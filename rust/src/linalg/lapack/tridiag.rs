//! Householder tridiagonalization of a symmetric matrix (LAPACK
//! dsytd2/dsytrd, lower-storage variant), explicit Q formation
//! (dorgtr) and reflector application (dormtr-style back-transform).
//!
//! All four symmetric eigensolver drivers ([`super::eig`]) share this
//! reduction — exactly as in LAPACK, where dsyev/dsyevd/dsyevx/dsyevr
//! differ only in the tridiagonal stage the paper's Fig. 5 compares.

use crate::linalg::blas1::{daxpy, ddot, dnrm2, dscal};
use crate::linalg::blas2::dsymv;
use crate::linalg::Uplo;

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

/// Generate an elementary Householder reflector H = I - tau·v·vᵀ with
/// v[0] = 1 such that H·x = (beta, 0, …, 0)ᵀ (LAPACK dlarfg).
/// `x[0]` is alpha on entry, beta on exit; `x[1..]` becomes v[1..].
/// Returns tau.
pub fn dlarfg(n: usize, x: &mut [f64], incx: usize) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let alpha = x[0];
    let xnorm = dnrm2(n - 1, &x[incx..], incx);
    if xnorm == 0.0 {
        return 0.0;
    }
    let beta = -(alpha.hypot(xnorm)).copysign(alpha);
    let tau = (beta - alpha) / beta;
    let scale = 1.0 / (alpha - beta);
    dscal(n - 1, scale, &mut x[incx..], incx);
    x[0] = beta;
    tau
}

/// Tridiagonalize a symmetric matrix stored in the lower triangle:
/// A = Q·T·Qᵀ. On exit the reflector vectors are stored below the first
/// subdiagonal of `a`; `d` receives the diagonal of T, `e` the
/// subdiagonal, `tau` the reflector scalars (LAPACK dsytd2, uplo='L').
pub fn dsytrd(n: usize, a: &mut [f64], lda: usize, d: &mut [f64], e: &mut [f64], tau: &mut [f64]) {
    for i in 0..n.saturating_sub(1) {
        let len = n - i - 1; // length of the column below the diagonal
        // Generate reflector to annihilate A(i+2.., i)
        let taui = dlarfg(len, &mut a[idx(i + 1, i, lda)..], 1);
        e[i] = a[idx(i + 1, i, lda)];
        tau[i] = taui;
        if taui != 0.0 {
            // Apply H to the trailing submatrix A(i+1.., i+1..):
            // with v = (1, A(i+2.., i)):
            a[idx(i + 1, i, lda)] = 1.0;
            // w := tau · A22 · v
            let mut w = vec![0.0f64; len];
            {
                let a22 = &a[idx(i + 1, i + 1, lda)..];
                let v = &a[idx(i + 1, i, lda)..idx(i + 1, i, lda) + len];
                dsymv(Uplo::Lower, len, taui, a22, lda, v, 1, 0.0, &mut w, 1);
            }
            // w := w - (tau/2)(wᵀv) v
            let vwdot = {
                let v = &a[idx(i + 1, i, lda)..idx(i + 1, i, lda) + len];
                ddot(len, &w, 1, v, 1)
            };
            {
                let v: Vec<f64> =
                    a[idx(i + 1, i, lda)..idx(i + 1, i, lda) + len].to_vec();
                daxpy(len, -0.5 * taui * vwdot, &v, 1, &mut w, 1);
                // rank-2 update of the lower triangle:
                // A22 := A22 - v·wᵀ - w·vᵀ
                for j in 0..len {
                    let vj = v[j];
                    let wj = w[j];
                    let col = idx(i + 1 + j, i + 1 + j, lda);
                    for r in j..len {
                        a[col + (r - j)] -= v[r] * wj + w[r] * vj;
                    }
                }
            }
            a[idx(i + 1, i, lda)] = e[i];
        }
        d[i] = a[idx(i, i, lda)];
    }
    if n > 0 {
        d[n - 1] = a[idx(n - 1, n - 1, lda)];
    }
}

/// Form Q explicitly from the dsytrd output (LAPACK dorgtr, lower).
/// `q` must be n×n with ldq ≥ n.
pub fn dorgtr(n: usize, a: &[f64], lda: usize, tau: &[f64], q: &mut [f64], ldq: usize) {
    // Q = H(0)·H(1)···H(n-3); start from identity and apply reflectors
    // from the last to the first.
    for j in 0..n {
        for i in 0..n {
            q[idx(i, j, ldq)] = if i == j { 1.0 } else { 0.0 };
        }
    }
    if n < 2 {
        return;
    }
    for i in (0..n - 1).rev() {
        apply_reflector_left(n, a, lda, tau, i, q, ldq);
    }
}

/// Apply H(i) (from dsytrd, lower) to the rows i+1.. of an n-column
/// matrix Z: Z := H(i)·Z. Shared by dorgtr and the eigensolver
/// back-transforms (dormtr 'L','L','N').
pub fn apply_reflector_left(
    n: usize,
    a: &[f64],
    lda: usize,
    tau: &[f64],
    i: usize,
    z: &mut [f64],
    ldz: usize,
) {
    let taui = tau[i];
    if taui == 0.0 {
        return;
    }
    let len = n - i - 1;
    // v = (1, A(i+2.., i)) acting on rows i+1..n
    let mut v = vec![0.0f64; len];
    v[0] = 1.0;
    if len > 1 {
        v[1..].copy_from_slice(&a[idx(i + 2, i, lda)..idx(i + 2, i, lda) + len - 1]);
    }
    for col in 0..n {
        let zcol = &mut z[col * ldz + i + 1..col * ldz + i + 1 + len];
        let s = ddot(len, &v, 1, zcol, 1);
        daxpy(len, -taui * s, &v, 1, zcol, 1);
    }
}

/// Multiply Q (implicit, from dsytrd) into a tridiagonal eigenvector
/// matrix: Z := Q·Z (LAPACK dormtr 'L','L','N' with Z n×m).
pub fn back_transform(
    n: usize,
    a: &[f64],
    lda: usize,
    tau: &[f64],
    z: &mut [f64],
    ldz: usize,
    ncols: usize,
) {
    if n < 2 {
        return;
    }
    // Q·Z = H(0)(H(1)(…H(n-3)·Z)) — apply last reflector first.
    for i in (0..n - 1).rev() {
        apply_reflector_left_cols(n, a, lda, tau, i, z, ldz, ncols);
    }
}

fn apply_reflector_left_cols(
    n: usize,
    a: &[f64],
    lda: usize,
    tau: &[f64],
    i: usize,
    z: &mut [f64],
    ldz: usize,
    ncols: usize,
) {
    let taui = tau[i];
    if taui == 0.0 {
        return;
    }
    let len = n - i - 1;
    let mut v = vec![0.0f64; len];
    v[0] = 1.0;
    if len > 1 {
        v[1..].copy_from_slice(&a[idx(i + 2, i, lda)..idx(i + 2, i, lda) + len - 1]);
    }
    for col in 0..ncols {
        let zcol = &mut z[col * ldz + i + 1..col * ldz + i + 1 + len];
        let s = ddot(len, &v, 1, zcol, 1);
        daxpy(len, -taui * s, &v, 1, zcol, 1);
    }
}

/// Assemble the explicit tridiagonal matrix T from d and e (test helper).
pub fn tridiagonal_matrix(d: &[f64], e: &[f64]) -> crate::linalg::Matrix {
    let n = d.len();
    let mut t = crate::linalg::Matrix::zeros(n, n);
    for i in 0..n {
        t[(i, i)] = d[i];
        if i + 1 < n {
            t[(i + 1, i)] = e[i];
            t[(i, i + 1)] = e[i];
        }
    }
    t
}

/// Check transposes are consistent (test helper): ‖QᵀQ − I‖_max.
pub fn orthogonality_error(q: &[f64], n: usize, ldq: usize) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += q[idx(k, i, ldq)] * q[idx(k, j, ldq)];
            }
            let target = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((s - target).abs());
        }
    }
    worst
}

#[allow(unused_imports)]
use crate::linalg::Matrix;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    fn symmetrize_lower(a: &Matrix) -> Matrix {
        let n = a.n;
        Matrix::from_fn(n, n, |i, j| if i >= j { a[(i, j)] } else { a[(j, i)] })
    }

    #[test]
    fn larfg_annihilates() {
        let mut x = vec![3.0, 4.0, 0.0, 0.0];
        let tau = dlarfg(2, &mut x, 1);
        // H x = (beta, 0): |beta| = ||x|| = 5
        assert!((x[0].abs() - 5.0).abs() < 1e-12);
        assert!(tau > 0.0 && tau <= 2.0);
    }

    #[test]
    fn sytrd_preserves_spectrum_structure() {
        let mut rng = Xoshiro256::seeded(60);
        let n = 12;
        let a0full = Matrix::random_spd(n, &mut rng);
        let mut a = a0full.clone();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n - 1];
        let mut tau = vec![0.0; n - 1];
        dsytrd(n, &mut a.data, n, &mut d, &mut e, &mut tau);
        // Q T Qᵀ == A0
        let mut q = Matrix::zeros(n, n);
        dorgtr(n, &a.data, n, &tau, &mut q.data, n);
        assert!(orthogonality_error(&q.data, n, n) < 1e-12);
        let t = tridiagonal_matrix(&d, &e);
        let rec = q.matmul(&t).matmul(&q.transpose());
        let sym = symmetrize_lower(&a0full);
        assert!(rec.max_abs_diff(&sym) < 1e-10, "diff={}", rec.max_abs_diff(&sym));
    }

    #[test]
    fn back_transform_matches_explicit_q() {
        let mut rng = Xoshiro256::seeded(61);
        let n = 9;
        let a0 = Matrix::random_spd(n, &mut rng);
        let mut a = a0.clone();
        let mut d = vec![0.0; n];
        let mut e = vec![0.0; n - 1];
        let mut tau = vec![0.0; n - 1];
        dsytrd(n, &mut a.data, n, &mut d, &mut e, &mut tau);
        let mut q = Matrix::zeros(n, n);
        dorgtr(n, &a.data, n, &tau, &mut q.data, n);
        let z0 = Matrix::random(n, 4, &mut rng);
        let expect = q.matmul(&z0);
        let mut z = z0.clone();
        back_transform(n, &a.data, n, &tau, &mut z.data, n, 4);
        assert!(z.max_abs_diff(&expect) < 1e-11);
    }

    #[test]
    fn sytrd_tiny_sizes() {
        // n = 0, 1, 2 edge cases must not panic
        let mut a = vec![4.0];
        let mut d = vec![0.0];
        dsytrd(1, &mut a, 1, &mut d, &mut [], &mut []);
        assert_eq!(d[0], 4.0);

        let mut a2 = vec![2.0, 1.0, 0.0, 3.0];
        let mut d2 = vec![0.0; 2];
        let mut e2 = vec![0.0; 1];
        let mut tau2 = vec![0.0; 1];
        dsytrd(2, &mut a2, 2, &mut d2, &mut e2, &mut tau2);
        assert!((d2[0] - 2.0).abs() < 1e-14);
        assert!((d2[1] - 3.0).abs() < 1e-14);
        assert!((e2[0].abs() - 1.0).abs() < 1e-14);
    }
}
