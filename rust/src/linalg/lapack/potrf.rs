//! Cholesky factorization (dpotrf), triangular solve after Cholesky
//! (dpotrs) and the combined driver (dposv) — the kernels at the heart
//! of the paper's GWAS study (Fig. 14).

use crate::linalg::blas3::{dgemm, dsyrk, dtrsm};
use crate::linalg::{Diag, LinalgError, Result, Side, Trans, Uplo};

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

/// Unblocked Cholesky: A = L·Lᵀ (Lower) or UᵀU (Upper), in place.
pub fn dpotrf_unblocked(uplo: Uplo, n: usize, a: &mut [f64], lda: usize) -> Result<()> {
    match uplo {
        Uplo::Lower => {
            for j in 0..n {
                let mut d = a[idx(j, j, lda)];
                for k in 0..j {
                    d -= a[idx(j, k, lda)] * a[idx(j, k, lda)];
                }
                if d <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite(j));
                }
                let d = d.sqrt();
                a[idx(j, j, lda)] = d;
                for i in j + 1..n {
                    let mut s = a[idx(i, j, lda)];
                    for k in 0..j {
                        s -= a[idx(i, k, lda)] * a[idx(j, k, lda)];
                    }
                    a[idx(i, j, lda)] = s / d;
                }
            }
        }
        Uplo::Upper => {
            for j in 0..n {
                let mut d = a[idx(j, j, lda)];
                for k in 0..j {
                    d -= a[idx(k, j, lda)] * a[idx(k, j, lda)];
                }
                if d <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite(j));
                }
                let d = d.sqrt();
                a[idx(j, j, lda)] = d;
                for i in j + 1..n {
                    let mut s = a[idx(j, i, lda)];
                    for k in 0..j {
                        s -= a[idx(k, j, lda)] * a[idx(k, i, lda)];
                    }
                    a[idx(j, i, lda)] = s / d;
                }
            }
        }
    }
    Ok(())
}

/// Blocked Cholesky (LAPACK dpotrf), Lower variant blocked, Upper
/// delegated per-block.
pub fn dpotrf(uplo: Uplo, n: usize, a: &mut [f64], lda: usize) -> Result<()> {
    dpotrf_nb(uplo, n, a, lda, 64)
}

/// Blocked Cholesky with explicit block size.
pub fn dpotrf_nb(uplo: Uplo, n: usize, a: &mut [f64], lda: usize, nb: usize) -> Result<()> {
    if nb <= 1 || nb >= n {
        return dpotrf_unblocked(uplo, n, a, lda);
    }
    if uplo == Uplo::Upper {
        // Factor the lower layout of Aᵀ: for simplicity use unblocked
        // for Upper (the experiments use Lower).
        return dpotrf_unblocked(uplo, n, a, lda);
    }
    let mut j = 0;
    while j < n {
        let jb = nb.min(n - j);
        // A11 -= L10 · L10ᵀ (syrk on the diagonal block)
        if j > 0 {
            // pack L10 (jb × j)
            let mut l10 = vec![0.0f64; jb * j];
            for c in 0..j {
                l10[c * jb..(c + 1) * jb]
                    .copy_from_slice(&a[idx(j, c, lda)..idx(j, c, lda) + jb]);
            }
            dsyrk(
                Uplo::Lower, Trans::No, jb, j, -1.0, &l10, jb, 1.0,
                &mut a[idx(j, j, lda)..], lda,
            );
            // A21 -= L20 · L10ᵀ
            if j + jb < n {
                let mrem = n - j - jb;
                let mut l20 = vec![0.0f64; mrem * j];
                for c in 0..j {
                    l20[c * mrem..(c + 1) * mrem]
                        .copy_from_slice(&a[idx(j + jb, c, lda)..idx(j + jb, c, lda) + mrem]);
                }
                dgemm(
                    Trans::No, Trans::Yes, mrem, jb, j, -1.0, &l20, mrem, &l10, jb, 1.0,
                    &mut a[idx(j + jb, j, lda)..], lda,
                );
            }
        }
        // factor diagonal block (in place, offset view)
        {
            let sub = &mut a[idx(j, j, lda)..];
            dpotrf_unblocked(Uplo::Lower, jb, sub, lda)
                .map_err(|e| match e {
                    LinalgError::NotPositiveDefinite(i) => {
                        LinalgError::NotPositiveDefinite(i + j)
                    }
                    other => other,
                })?;
        }
        // L21 := A21 · L11⁻ᵀ
        if j + jb < n {
            let mrem = n - j - jb;
            let mut l11 = vec![0.0f64; jb * jb];
            for c in 0..jb {
                l11[c * jb..(c + 1) * jb]
                    .copy_from_slice(&a[idx(j, j + c, lda)..idx(j, j + c, lda) + jb]);
            }
            dtrsm(
                Side::Right, Uplo::Lower, Trans::Yes, Diag::NonUnit, mrem, jb, 1.0,
                &l11, jb, &mut a[idx(j + jb, j, lda)..], lda,
            );
        }
        j += jb;
    }
    Ok(())
}

/// Solve A·X = B given the Cholesky factor (LAPACK dpotrs).
pub fn dpotrs(
    uplo: Uplo,
    n: usize,
    nrhs: usize,
    a: &[f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
) {
    match uplo {
        Uplo::Lower => {
            dtrsm(Side::Left, Uplo::Lower, Trans::No, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
            dtrsm(Side::Left, Uplo::Lower, Trans::Yes, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
        }
        Uplo::Upper => {
            dtrsm(Side::Left, Uplo::Upper, Trans::Yes, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
            dtrsm(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
        }
    }
}

/// Cholesky solve driver: factor + solve (LAPACK dposv).
pub fn dposv(
    uplo: Uplo,
    n: usize,
    nrhs: usize,
    a: &mut [f64],
    lda: usize,
    b: &mut [f64],
    ldb: usize,
) -> Result<()> {
    dpotrf(uplo, n, a, lda)?;
    dpotrs(uplo, n, nrhs, a, lda, b, ldb);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn potrf_lower_reconstructs() {
        let mut rng = Xoshiro256::seeded(40);
        let n = 24;
        let a0 = Matrix::random_spd(n, &mut rng);
        let mut a = a0.clone();
        dpotrf_nb(Uplo::Lower, n, &mut a.data, n, 8).unwrap();
        // L·Lᵀ == A0
        let mut l = Matrix::zeros(n, n);
        for j in 0..n {
            for i in j..n {
                l[(i, j)] = a[(i, j)];
            }
        }
        let rec = l.matmul(&l.transpose());
        assert!(rec.max_abs_diff(&a0) < 1e-10);
    }

    #[test]
    fn potrf_upper_reconstructs() {
        let mut rng = Xoshiro256::seeded(41);
        let n = 12;
        let a0 = Matrix::random_spd(n, &mut rng);
        let mut a = a0.clone();
        dpotrf_unblocked(Uplo::Upper, n, &mut a.data, n).unwrap();
        let mut u = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..=j {
                u[(i, j)] = a[(i, j)];
            }
        }
        let rec = u.transpose().matmul(&u);
        assert!(rec.max_abs_diff(&a0) < 1e-10);
    }

    #[test]
    fn posv_solves_both_uplos() {
        let mut rng = Xoshiro256::seeded(42);
        let n = 30;
        let nrhs = 4;
        for &uplo in &[Uplo::Lower, Uplo::Upper] {
            let a0 = Matrix::random_spd(n, &mut rng);
            let x = Matrix::random(n, nrhs, &mut rng);
            let b0 = a0.matmul(&x);
            let mut a = a0.clone();
            let mut b = b0.clone();
            dposv(uplo, n, nrhs, &mut a.data, n, &mut b.data, n).unwrap();
            assert!(b.max_abs_diff(&x) < 1e-9, "{uplo:?}");
        }
    }

    #[test]
    fn not_positive_definite_detected() {
        let mut a = Matrix::identity(3);
        a[(2, 2)] = -1.0;
        let err = dpotrf_unblocked(Uplo::Lower, 3, &mut a.data, 3).unwrap_err();
        assert_eq!(err, LinalgError::NotPositiveDefinite(2));
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut rng = Xoshiro256::seeded(43);
        let n = 29;
        let a0 = Matrix::random_spd(n, &mut rng);
        let mut au = a0.clone();
        dpotrf_unblocked(Uplo::Lower, n, &mut au.data, n).unwrap();
        let mut ab = a0.clone();
        dpotrf_nb(Uplo::Lower, n, &mut ab.data, n, 7).unwrap();
        // compare lower triangles
        for j in 0..n {
            for i in j..n {
                assert!((au[(i, j)] - ab[(i, j)]).abs() < 1e-11);
            }
        }
    }
}
