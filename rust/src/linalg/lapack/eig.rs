//! Symmetric eigensolvers: the four LAPACK drivers the paper compares
//! in its scalability study (Fig. 5), implemented from scratch on top
//! of the shared tridiagonal reduction ([`super::tridiag`]):
//!
//! * [`dsyev`]  — implicit QL/QR iteration on T (dsteqr),
//! * [`dsyevd`] — Cuppen divide & conquer with a secular-equation
//!   solver (dstedc, simplified deflation),
//! * [`dsyevx`] — bisection (dstebz) + inverse iteration (dstein),
//! * [`dsyevr`] — bisection + single-solve twisted factorization
//!   (simplified MRRR: no representation tree; clustered eigenvalues
//!   fall back to Gram-Schmidt like dstein).
//!
//! All drivers produce ascending eigenvalues and (optionally)
//! orthonormal eigenvectors of the dense symmetric input.

use super::tridiag::{back_transform, dsytrd};
use crate::linalg::{LinalgError, Result};

const EPS: f64 = f64::EPSILON;

/// Eigendecomposition result: ascending eigenvalues, optional
/// column-eigenvectors (n×n, column j ↔ eigenvalue j).
#[derive(Debug, Clone)]
pub struct EigResult {
    pub values: Vec<f64>,
    pub vectors: Option<Vec<f64>>, // column-major n×n, ld = n
}

// ---------------------------------------------------------------------
// dsteqr: implicit QL with Wilkinson shift (EISPACK tql2 lineage).
// ---------------------------------------------------------------------

/// Eigenvalues (and optionally eigenvectors accumulated into `z`,
/// n×n ld=n, which must start as the basis to rotate — identity for
/// tridiagonal eigenvectors) of a symmetric tridiagonal matrix.
pub fn dsteqr(d: &mut [f64], e: &mut [f64], mut z: Option<&mut [f64]>) -> Result<()> {
    let n = d.len();
    if n <= 1 {
        return Ok(());
    }
    let mut e = {
        // work on a padded copy so e[l..m] indexing is uniform
        let mut ee = vec![0.0f64; n];
        ee[..n - 1].copy_from_slice(&e[..n - 1]);
        ee
    };
    for l in 0..n {
        let mut iter = 0;
        loop {
            // find the first small subdiagonal element at or after l
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= EPS * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 80 {
                return Err(LinalgError::NoConvergence(l));
            }
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c, mut p) = (1.0f64, 1.0f64, 0.0f64);
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    // recover from underflow
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // accumulate rotation into z (columns i and i+1)
                if let Some(zz) = z.as_deref_mut() {
                    for k in 0..n {
                        f = zz[k + (i + 1) * n];
                        zz[k + (i + 1) * n] = s * zz[k + i * n] + c * f;
                        zz[k + i * n] = c * zz[k + i * n] - s * f;
                    }
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // sort ascending, carrying z columns
    sort_eigenpairs(d, z.as_deref_mut());
    Ok(())
}

fn sort_eigenpairs(d: &mut [f64], z: Option<&mut [f64]>) {
    let n = d.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let sorted_d: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    d.copy_from_slice(&sorted_d);
    if let Some(zz) = z {
        let old = zz.to_vec();
        for (newj, &oldj) in order.iter().enumerate() {
            zz[newj * n..(newj + 1) * n].copy_from_slice(&old[oldj * n..(oldj + 1) * n]);
        }
    }
}

// ---------------------------------------------------------------------
// dstedc: Cuppen divide & conquer.
// ---------------------------------------------------------------------

const DC_CUTOFF: usize = 24;

/// Divide & conquer tridiagonal eigensolver (LAPACK dstedc,
/// simplified deflation: only |z_i| ≈ 0 deflates). When `want_z`,
/// returns the tridiagonal eigenvector matrix (n×n, column-major).
/// Values-only falls back to QL iteration, exactly as LAPACK's dsyevd
/// (jobz='N') calls dsterf.
pub fn dstedc(d: &mut [f64], e: &[f64], want_z: bool) -> Result<Option<Vec<f64>>> {
    let n = d.len();
    if !want_z {
        let mut ebuf = e.to_vec();
        dsteqr(d, &mut ebuf, None)?;
        return Ok(None);
    }
    let mut evec = Some(identity(n));
    let e = e.to_vec();
    stedc_rec(d, &e, evec.as_deref_mut(), n)?;
    Ok(evec)
}

fn identity(n: usize) -> Vec<f64> {
    let mut z = vec![0.0f64; n * n];
    for i in 0..n {
        z[i + i * n] = 1.0;
    }
    z
}

fn stedc_rec(d: &mut [f64], e: &[f64], z: Option<&mut [f64]>, ldz: usize) -> Result<()> {
    let n = d.len();
    if n <= DC_CUTOFF {
        // base case: QL iteration. Need a compact z to rotate.
        let mut ebuf = e.to_vec();
        match z {
            None => dsteqr(d, &mut ebuf, None),
            Some(zz) => {
                let mut small = identity(n);
                dsteqr(d, &mut ebuf, Some(&mut small))?;
                for j in 0..n {
                    zz[j * ldz..j * ldz + n].copy_from_slice(&small[j * n..(j + 1) * n]);
                }
                Ok(())
            }
        }?;
        return Ok(());
    }
    let m = n / 2;
    // rank-one tear: rho = |e[m-1]|, w = (…,1, s,…) with s = sign(e[m-1])
    let rho = e[m - 1].abs();
    let sign = if e[m - 1] >= 0.0 { 1.0 } else { -1.0 };
    let (d1, d2) = d.split_at_mut(m);
    d1[m - 1] -= rho;
    d2[0] -= rho;
    // recurse on the two halves
    match z {
        None => unreachable!("values-only D&C handled by dstedc via QL"),
        Some(zz) => {
            // eigenvectors live in the caller's zz: columns [0,m) rows
            // [0,m), and columns [m,n) rows [m,n) (block diagonal).
            {
                let (zcols1, zcols2) = zz.split_at_mut(m * ldz);
                stedc_rec(d1, &e[..m - 1], Some(zcols1), ldz)?;
                // second block occupies rows m.. of columns m..n: shift
                // the base pointer by m so the block writes rows m..n.
                stedc_rec(d2, &e[m..], Some(&mut zcols2[m..]), ldz)?;
            }
            // build z = (last row of Q1 | sign · first row of Q2)
            let mut zvec = vec![0.0f64; n];
            for j in 0..m {
                zvec[j] = zz[(m - 1) + j * ldz];
            }
            for j in m..n {
                zvec[j] = sign * zz[m + j * ldz];
            }
            let mut dall = d.to_vec();
            let (lam, u) = secular_merge(&mut dall, &zvec, rho, true)?;
            let umat = u.unwrap(); // n×n: column j = unit eigvec in D-basis
            // new vectors: Znew[:, j] = Zblock · u_j
            let mut newz = vec![0.0f64; n * n];
            for j in 0..n {
                for k in 0..n {
                    let ukj = umat[k + j * n];
                    if ukj != 0.0 {
                        // column k of the block-diagonal Z
                        let (rows, base) = if k < m { (0..m, 0) } else { (m..n, 0) };
                        let _ = base;
                        for r in rows {
                            newz[r + j * n] += zz[r + k * ldz] * ukj;
                        }
                    }
                }
            }
            for j in 0..n {
                zz[j * ldz..j * ldz + n].copy_from_slice(&newz[j * n..(j + 1) * n]);
            }
            d.copy_from_slice(&lam);
            Ok(())
        }
    }
}

/// Solve the secular equation f(λ) = 1 + rho Σ z_i²/(d_i − λ) = 0 for
/// all n roots of D + rho·z·zᵀ (rho ≥ 0). `d` is sorted ascending on
/// entry (sorted here if not). Returns ascending eigenvalues and, if
/// `want_u`, the normalized eigenvectors in the D-basis.
fn secular_merge(
    d: &mut [f64],
    z: &[f64],
    rho: f64,
    want_u: bool,
) -> Result<(Vec<f64>, Option<Vec<f64>>)> {
    let n = d.len();
    // sort (d, z) ascending
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let ds: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let zs: Vec<f64> = order.iter().map(|&i| z[i]).collect();
    let znorm2: f64 = zs.iter().map(|v| v * v).sum();
    let scale = ds.iter().fold(1.0f64, |m, v| m.max(v.abs())) + rho * znorm2;

    // Deflate: entries with negligible weight keep their eigenvalue
    // d_i and unit eigenvector e_i; the secular equation is solved on
    // the reduced set of non-deflated poles only (as in LAPACK dlaed2).
    let mut deflated = vec![false; n];
    for i in 0..n {
        if rho * zs[i] * zs[i] <= EPS * scale * 16.0 {
            deflated[i] = true;
        }
    }
    let red: Vec<usize> = (0..n).filter(|&i| !deflated[i]).collect();
    let k = red.len();
    let dr: Vec<f64> = red.iter().map(|&i| ds[i]).collect();
    let zr2: Vec<f64> = red.iter().map(|&i| zs[i] * zs[i]).collect();
    let f = |x: f64| -> f64 {
        let mut s = 1.0;
        for i in 0..k {
            s += rho * zr2[i] / (dr[i] - x);
        }
        s
    };
    // Roots of the reduced problem interlace its poles strictly:
    // root j in (dr_j, dr_{j+1}), last in (dr_{k-1}, dr_{k-1}+rho*sum z^2).
    // f -> -inf at each pole+ and +inf at the next pole-, and f is
    // increasing in between, so sign-bisection without endpoint
    // evaluation is safe.
    let mut roots = vec![0.0f64; k];
    for j in 0..k {
        let lo0 = dr[j];
        let hi0 = if j + 1 < k {
            dr[j + 1]
        } else {
            dr[k - 1] + rho * znorm2 + scale * EPS
        };
        if hi0 - lo0 <= EPS * scale {
            roots[j] = lo0; // (near-)degenerate pole pair
            continue;
        }
        let (mut lo, mut hi) = (lo0, hi0);
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break;
            }
            if f(mid) < 0.0 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        roots[j] = 0.5 * (lo + hi);
        if !roots[j].is_finite() {
            return Err(LinalgError::NoConvergence(j));
        }
    }
    // assemble all n eigenvalues in the sorted-D index space
    let mut lam = vec![0.0f64; n];
    {
        let mut rj = 0;
        for i in 0..n {
            if deflated[i] {
                lam[i] = ds[i];
            } else {
                lam[i] = roots[rj];
                rj += 1;
            }
        }
    }
    // eigenvectors in D basis
    let u = if want_u {
        let mut u = vec![0.0f64; n * n];
        for j in 0..n {
            if deflated[j] {
                u[j + j * n] = 1.0;
                continue;
            }
            let mut norm = 0.0;
            for i in 0..n {
                let v = if deflated[i] { 0.0 } else { zs[i] / (ds[i] - lam[j]) };
                u[i + j * n] = v;
                norm += v * v;
            }
            let norm = norm.sqrt();
            for i in 0..n {
                u[i + j * n] /= norm;
            }
        }
        Some(u)
    } else {
        None
    };
    // un-sort: map back to caller's original D order for the U rows
    let mut lam_sorted = lam.clone();
    lam_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let u_reordered = u.map(|us| {
        // rows of U correspond to sorted ds; un-permute rows to the
        // caller's original order, and order columns by ascending λ.
        let mut colorder: Vec<usize> = (0..n).collect();
        colorder.sort_by(|&a, &b| lam[a].partial_cmp(&lam[b]).unwrap());
        let mut out = vec![0.0f64; n * n];
        for (newj, &oldj) in colorder.iter().enumerate() {
            for i in 0..n {
                out[order[i] + newj * n] = us[i + oldj * n];
            }
        }
        out
    });
    Ok((lam_sorted, u_reordered))
}

// ---------------------------------------------------------------------
// dstebz: bisection eigenvalues via Sturm counts.
// ---------------------------------------------------------------------

/// Number of eigenvalues of T strictly less than `x` (Sturm count).
pub fn sturm_count(d: &[f64], e: &[f64], x: f64) -> usize {
    let n = d.len();
    let mut count = 0;
    let mut q = 1.0f64;
    for i in 0..n {
        let e2 = if i == 0 { 0.0 } else { e[i - 1] * e[i - 1] };
        q = d[i] - x - if i == 0 { 0.0 } else { e2 / q };
        if q == 0.0 {
            q = EPS * (d[i].abs() + e2.sqrt() + 1.0);
        }
        if q < 0.0 {
            count += 1;
        }
    }
    count
}

/// All eigenvalues of a symmetric tridiagonal matrix by bisection
/// (LAPACK dstebz, range='A'), ascending, to ~machine precision.
pub fn dstebz(d: &[f64], e: &[f64], abstol: f64) -> Vec<f64> {
    let n = d.len();
    if n == 0 {
        return vec![];
    }
    // Gershgorin interval
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..n {
        let r = (if i > 0 { e[i - 1].abs() } else { 0.0 })
            + (if i + 1 < n { e[i].abs() } else { 0.0 });
        lo = lo.min(d[i] - r);
        hi = hi.max(d[i] + r);
    }
    let width = (hi - lo).max(1.0);
    lo -= width * EPS * 2.0 + abstol;
    hi += width * EPS * 2.0 + abstol;
    let tol = if abstol > 0.0 { abstol } else { EPS * width * 2.0 };
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        // find the k-th smallest eigenvalue: smallest x with count(x) > k
        let (mut a, mut b) = (lo, hi);
        while b - a > tol.max(EPS * (a.abs() + b.abs())) {
            let mid = 0.5 * (a + b);
            if sturm_count(d, e, mid) > k {
                b = mid;
            } else {
                a = mid;
            }
        }
        out.push(0.5 * (a + b));
    }
    out
}

// ---------------------------------------------------------------------
// dstein: inverse iteration.
// ---------------------------------------------------------------------

/// Solve (T − λI) x = b with a tridiagonal LU (partial pivoting),
/// overwriting `x` (which holds b on entry). Internal helper.
fn tridiag_shifted_solve(d: &[f64], e: &[f64], lambda: f64, x: &mut [f64]) {
    let n = d.len();
    if n == 1 {
        let dd = d[0] - lambda;
        x[0] /= if dd.abs() > EPS { dd } else { EPS };
        return;
    }
    // Gaussian elimination with partial pivoting on the tridiagonal;
    // band grows to 2 superdiagonals.
    let mut diag: Vec<f64> = d.iter().map(|v| v - lambda).collect();
    let mut sup1 = e.to_vec(); // superdiag
    let mut sup2 = vec![0.0f64; n.saturating_sub(2)];
    let sub = e.to_vec(); // subdiag (const copy)
    for i in 0..n - 1 {
        let (piv, other) = (diag[i], sub[i]);
        if other.abs() > piv.abs() {
            // swap row i with row i+1
            let (a, b, c) = (diag[i + 1], sup1.get(i + 1).copied().unwrap_or(0.0), 0.0f64);
            diag[i] = sub[i];
            let olds1 = sup1[i];
            sup1[i] = a;
            if i + 2 < n {
                sup2[i] = b;
            }
            let _ = c;
            // new row i+1 = old row i
            diag[i + 1] = olds1;
            if i + 2 < n {
                sup1[i + 1] = 0.0;
            }
            x.swap(i, i + 1);
            // eliminate: factor = old_diag_i / new pivot
            let f = piv / if diag[i].abs() > 0.0 { diag[i] } else { EPS };
            diag[i + 1] -= f * sup1[i];
            if i + 2 < n {
                sup1[i + 1] -= f * sup2[i];
            }
            x[i + 1] -= f * x[i];
        } else {
            let p = if piv.abs() > 0.0 { piv } else { EPS };
            let f = other / p;
            diag[i + 1] -= f * sup1[i];
            if i + 2 < n {
                // sup2[i] stays 0 in the no-swap case
            }
            x[i + 1] -= f * x[i];
        }
    }
    // back substitution
    for i in (0..n).rev() {
        let mut s = x[i];
        if i + 1 < n {
            s -= sup1[i] * x[i + 1];
        }
        if i + 2 < n {
            s -= sup2[i] * x[i + 2];
        }
        let p = if diag[i].abs() > EPS { diag[i] } else { EPS.copysign(diag[i]) };
        x[i] = s / p;
    }
}

/// Inverse iteration for the eigenvectors of a tridiagonal matrix given
/// eigenvalues (LAPACK dstein). Reorthogonalizes within clusters of
/// close eigenvalues. Returns n×k column-major vectors.
pub fn dstein(d: &[f64], e: &[f64], lambdas: &[f64]) -> Vec<f64> {
    let n = d.len();
    let k = lambdas.len();
    let mut z = vec![0.0f64; n * k];
    let mut rng = crate::util::rng::Xoshiro256::seeded(0x5713);
    let spread = lambdas.last().copied().unwrap_or(1.0) - lambdas.first().copied().unwrap_or(0.0);
    let cluster_tol = (spread.abs().max(1.0)) * 1e-7;
    for j in 0..k {
        let col_range = j * n..(j + 1) * n;
        // deterministic pseudo-random start
        for v in &mut z[col_range.clone()] {
            *v = rng.next_open01() - 0.5;
        }
        for _ in 0..4 {
            let col = &mut z[col_range.clone()];
            tridiag_shifted_solve(d, e, lambdas[j], col);
            // orthogonalize against previous vectors in the cluster
            let mut jj = j;
            while jj > 0 && (lambdas[j] - lambdas[jj - 1]).abs() < cluster_tol {
                jj -= 1;
            }
            for prev in jj..j {
                let mut dot = 0.0;
                for i in 0..n {
                    dot += z[prev * n + i] * z[j * n + i];
                }
                for i in 0..n {
                    z[j * n + i] -= dot * z[prev * n + i];
                }
            }
            // normalize
            let mut norm = 0.0;
            for v in &z[col_range.clone()] {
                norm += v * v;
            }
            let norm = norm.sqrt();
            if norm > 0.0 {
                for v in &mut z[col_range.clone()] {
                    *v /= norm;
                }
            }
        }
    }
    z
}

// ---------------------------------------------------------------------
// twisted factorization (simplified MRRR kernel for dsyevr)
// ---------------------------------------------------------------------

/// Eigenvector of T for an isolated eigenvalue λ via twisted
/// factorization: forward LDLᵀ + backward UDUᵀ, twist at the index
/// minimizing |γ|, one triangular solve — no iteration.
pub fn twisted_eigenvector(d: &[f64], e: &[f64], lambda: f64) -> Vec<f64> {
    let n = d.len();
    let mut x = vec![0.0f64; n];
    if n == 1 {
        x[0] = 1.0;
        return x;
    }
    // forward: s[i] (D+ diagonal), l[i] = e[i]/s[i]
    let mut s = vec![0.0f64; n];
    let mut l = vec![0.0f64; n - 1];
    s[0] = d[0] - lambda;
    for i in 0..n - 1 {
        let si = if s[i] != 0.0 { s[i] } else { EPS };
        l[i] = e[i] / si;
        s[i + 1] = d[i + 1] - lambda - e[i] * l[i];
    }
    // backward: p[i] (D− diagonal), u[i] = e[i]/p[i+1]
    let mut p = vec![0.0f64; n];
    let mut u = vec![0.0f64; n - 1];
    p[n - 1] = d[n - 1] - lambda;
    for i in (0..n - 1).rev() {
        let pi = if p[i + 1] != 0.0 { p[i + 1] } else { EPS };
        u[i] = e[i] / pi;
        p[i] = d[i] - lambda - e[i] * u[i];
    }
    // twist index: γ_k = s_k + p_k − (d_k − λ)
    let mut kbest = 0;
    let mut gbest = f64::INFINITY;
    for kk in 0..n {
        let g = (s[kk] + p[kk] - (d[kk] - lambda)).abs();
        if g < gbest {
            gbest = g;
            kbest = kk;
        }
    }
    x[kbest] = 1.0;
    for i in (0..kbest).rev() {
        x[i] = -l[i] * x[i + 1];
    }
    for i in kbest..n - 1 {
        x[i + 1] = -u[i] * x[i];
    }
    let norm: f64 = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in &mut x {
        *v /= norm;
    }
    x
}

// ---------------------------------------------------------------------
// dense drivers
// ---------------------------------------------------------------------

fn reduce(a: &mut [f64], n: usize, lda: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n.saturating_sub(1)];
    let mut tau = vec![0.0f64; n.saturating_sub(1)];
    dsytrd(n, a, lda, &mut d, &mut e, &mut tau);
    (d, e, tau)
}

/// dsyev: QL/QR iteration driver. `a` (lower symmetric, n×n, ld=lda)
/// is destroyed. `want_vectors` selects jobz='V'.
pub fn dsyev(n: usize, a: &mut [f64], lda: usize, want_vectors: bool) -> Result<EigResult> {
    let (mut d, mut e, tau) = reduce(a, n, lda);
    if !want_vectors {
        dsteqr(&mut d, &mut e, None)?;
        return Ok(EigResult { values: d, vectors: None });
    }
    let mut z = identity(n);
    dsteqr(&mut d, &mut e, Some(&mut z))?;
    back_transform(n, a, lda, &tau, &mut z, n, n);
    Ok(EigResult { values: d, vectors: Some(z) })
}

/// dsyevd: divide & conquer driver.
pub fn dsyevd(n: usize, a: &mut [f64], lda: usize, want_vectors: bool) -> Result<EigResult> {
    let (mut d, e, tau) = reduce(a, n, lda);
    let z = dstedc(&mut d, &e, want_vectors)?;
    let vectors = match z {
        None => None,
        Some(mut z) => {
            back_transform(n, a, lda, &tau, &mut z, n, n);
            Some(z)
        }
    };
    // dstedc returns ascending values already (secular merge sorts)
    Ok(EigResult { values: d, vectors })
}

/// dsyevx: bisection + inverse iteration driver (range='A').
pub fn dsyevx(n: usize, a: &mut [f64], lda: usize, want_vectors: bool) -> Result<EigResult> {
    let (d, e, tau) = reduce(a, n, lda);
    let lambdas = dstebz(&d, &e, 0.0);
    if !want_vectors {
        return Ok(EigResult { values: lambdas, vectors: None });
    }
    let mut z = dstein(&d, &e, &lambdas);
    back_transform(n, a, lda, &tau, &mut z, n, n);
    Ok(EigResult { values: lambdas, vectors: Some(z) })
}

/// dsyevr: bisection + twisted-factorization driver (simplified MRRR).
/// Isolated eigenvalues get a single twisted solve; clustered ones are
/// Gram-Schmidt re-orthogonalized.
pub fn dsyevr(n: usize, a: &mut [f64], lda: usize, want_vectors: bool) -> Result<EigResult> {
    let (d, e, tau) = reduce(a, n, lda);
    let lambdas = dstebz(&d, &e, 0.0);
    if !want_vectors {
        return Ok(EigResult { values: lambdas, vectors: None });
    }
    let mut z = vec![0.0f64; n * n];
    let spread =
        lambdas.last().copied().unwrap_or(1.0) - lambdas.first().copied().unwrap_or(0.0);
    let cluster_tol = spread.abs().max(1.0) * 1e-7;
    for j in 0..n {
        let v = twisted_eigenvector(&d, &e, lambdas[j]);
        z[j * n..(j + 1) * n].copy_from_slice(&v);
        // cluster fallback: orthogonalize against close predecessors
        let mut jj = j;
        while jj > 0 && (lambdas[j] - lambdas[jj - 1]).abs() < cluster_tol {
            jj -= 1;
        }
        if jj < j {
            for prev in jj..j {
                let mut dot = 0.0;
                for i in 0..n {
                    dot += z[prev * n + i] * z[j * n + i];
                }
                for i in 0..n {
                    z[j * n + i] -= dot * z[prev * n + i];
                }
            }
            let norm: f64 =
                z[j * n..(j + 1) * n].iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm > 0.0 {
                for v in &mut z[j * n..(j + 1) * n] {
                    *v /= norm;
                }
            }
        }
    }
    back_transform(n, a, lda, &tau, &mut z, n, n);
    Ok(EigResult { values: lambdas, vectors: Some(z) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    fn symmetrize_lower(a: &Matrix) -> Matrix {
        let n = a.n;
        Matrix::from_fn(n, n, |i, j| if i >= j { a[(i, j)] } else { a[(j, i)] })
    }

    fn check_driver(
        driver: fn(usize, &mut [f64], usize, bool) -> Result<EigResult>,
        n: usize,
        seed: u64,
        tol: f64,
    ) {
        let mut rng = Xoshiro256::seeded(seed);
        let a0 = Matrix::random_spd(n, &mut rng);
        let sym = symmetrize_lower(&a0);
        let mut a = a0.clone();
        let res = driver(n, &mut a.data, n, true).unwrap();
        // ascending
        for w in res.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
        // residuals ‖A v − λ v‖
        let z = res.vectors.as_ref().unwrap();
        let anorm = sym.frobenius();
        for j in 0..n {
            let v = &z[j * n..(j + 1) * n];
            let mut resid = 0.0f64;
            for i in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += sym[(i, k)] * v[k];
                }
                resid = resid.max((s - res.values[j] * v[i]).abs());
            }
            assert!(resid < tol * anorm, "col {j}: resid {resid} anorm {anorm}");
        }
        // orthogonality
        let err = super::super::tridiag::orthogonality_error(z, n, n);
        assert!(err < tol * 100.0, "orthogonality error {err}");
        // trace preserved
        let tr: f64 = (0..n).map(|i| sym[(i, i)]).sum();
        let sum: f64 = res.values.iter().sum();
        assert!((tr - sum).abs() < 1e-8 * tr.abs());
    }

    #[test]
    fn syev_small() {
        check_driver(dsyev, 15, 70, 1e-10);
    }

    #[test]
    fn syevd_small() {
        check_driver(dsyevd, 15, 71, 1e-8);
    }

    #[test]
    fn syevd_crosses_dc_cutoff() {
        check_driver(dsyevd, 60, 72, 1e-8);
    }

    #[test]
    fn syevx_small() {
        check_driver(dsyevx, 15, 73, 1e-8);
    }

    #[test]
    fn syevr_small() {
        check_driver(dsyevr, 15, 74, 1e-8);
    }

    #[test]
    fn syevr_medium() {
        check_driver(dsyevr, 40, 75, 1e-7);
    }

    #[test]
    fn drivers_agree_on_values() {
        let n = 25;
        let mut rng = Xoshiro256::seeded(76);
        let a0 = Matrix::random_spd(n, &mut rng);
        let run = |f: fn(usize, &mut [f64], usize, bool) -> Result<EigResult>| {
            let mut a = a0.clone();
            f(n, &mut a.data, n, false).unwrap().values
        };
        let v1 = run(dsyev);
        let v2 = run(dsyevd);
        let v3 = run(dsyevx);
        let v4 = run(dsyevr);
        for i in 0..n {
            assert!((v1[i] - v2[i]).abs() < 1e-7 * v1[i].abs().max(1.0), "d&c {i}");
            assert!((v1[i] - v3[i]).abs() < 1e-7 * v1[i].abs().max(1.0), "bisect {i}");
            assert!((v1[i] - v4[i]).abs() < 1e-7 * v1[i].abs().max(1.0), "mrrr {i}");
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] — eigenvalues 1 and 3
        let mut a = vec![2.0, 1.0, 1.0, 2.0];
        let res = dsyev(2, &mut a, 2, true).unwrap();
        assert!((res.values[0] - 1.0).abs() < 1e-12);
        assert!((res.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sturm_count_splits_spectrum() {
        // tridiagonal with d = [1,2,3], e = [0,0] — eigenvalues 1,2,3
        let d = [1.0, 2.0, 3.0];
        let e = [0.0, 0.0];
        assert_eq!(sturm_count(&d, &e, 0.5), 0);
        assert_eq!(sturm_count(&d, &e, 1.5), 1);
        assert_eq!(sturm_count(&d, &e, 2.5), 2);
        assert_eq!(sturm_count(&d, &e, 3.5), 3);
    }

    #[test]
    fn stebz_diagonal_matrix() {
        let d = [3.0, 1.0, 2.0];
        let e = [0.0, 0.0];
        let ev = dstebz(&d, &e, 0.0);
        assert!((ev[0] - 1.0).abs() < 1e-9);
        assert!((ev[1] - 2.0).abs() < 1e-9);
        assert!((ev[2] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn twisted_matches_known_eigvec() {
        // T = [[2,1],[1,2]], λ=1 → v = (1,-1)/√2
        let v = twisted_eigenvector(&[2.0, 2.0], &[1.0], 1.0);
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((v[0] + v[1]).abs() < 1e-8);
    }
}
