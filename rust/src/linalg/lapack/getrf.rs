//! LU factorization with partial pivoting (dgetrf), row interchanges
//! (dlaswp), and the linear-system drivers dgetrs / dgesv.

use crate::linalg::blas1::{dscal, idamax};
use crate::linalg::blas3::{dgemm, dtrsm};
use crate::linalg::{Diag, LinalgError, Result, Side, Trans, Uplo};

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

/// Unblocked right-looking LU with partial pivoting of an m×n matrix.
/// On exit A holds L (unit diagonal, below) and U (on/above diagonal);
/// `ipiv[i] = p` means row i was swapped with row p (0-based, LAPACK
/// style but 0-indexed). Returns `Err(Singular(i))` on an exactly zero
/// pivot (factorization still completes LAPACK-style up to that point).
pub fn dgetrf_unblocked(
    m: usize,
    n: usize,
    a: &mut [f64],
    lda: usize,
    ipiv: &mut [usize],
) -> Result<()> {
    let mn = m.min(n);
    let mut first_singular: Option<usize> = None;
    for j in 0..mn {
        // pivot search in column j, rows j..m
        let p = j + idamax(m - j, &a[idx(j, j, lda)..], 1);
        ipiv[j] = p;
        if a[idx(p, j, lda)] == 0.0 {
            first_singular.get_or_insert(j);
            continue;
        }
        if p != j {
            // swap rows j and p across all n columns
            for col in 0..n {
                a.swap(idx(j, col, lda), idx(p, col, lda));
            }
        }
        // scale column below pivot
        let pivot = a[idx(j, j, lda)];
        dscal(m - j - 1, 1.0 / pivot, &mut a[idx(j + 1, j, lda)..], 1);
        // rank-1 trailing update: A[j+1.., j+1..] -= l * u
        for col in j + 1..n {
            let u = a[idx(j, col, lda)];
            if u != 0.0 {
                for row in j + 1..m {
                    let l = a[idx(row, j, lda)];
                    a[idx(row, col, lda)] -= l * u;
                }
            }
        }
    }
    match first_singular {
        Some(i) => Err(LinalgError::Singular(i)),
        None => Ok(()),
    }
}

/// Apply row interchanges `ipiv[k1..k2]` to an n-column matrix
/// (LAPACK dlaswp, forward direction, 0-based pivots).
pub fn dlaswp(n: usize, a: &mut [f64], lda: usize, k1: usize, k2: usize, ipiv: &[usize]) {
    for i in k1..k2 {
        let p = ipiv[i];
        if p != i {
            // swap rows i and p; row elements are strided by lda, so a
            // flat split cannot separate them — swap element-wise.
            for col in 0..n {
                a.swap(i + col * lda, p + col * lda);
            }
        }
    }
}

/// Blocked right-looking LU with partial pivoting (LAPACK dgetrf).
/// Panel factorization via [`dgetrf_unblocked`], trailing update via
/// dtrsm + dgemm.
pub fn dgetrf(m: usize, n: usize, a: &mut [f64], lda: usize, ipiv: &mut [usize]) -> Result<()> {
    dgetrf_nb(m, n, a, lda, ipiv, 64)
}

/// Blocked LU with explicit block size (exposed for the paper's
/// block-size studies).
pub fn dgetrf_nb(
    m: usize,
    n: usize,
    a: &mut [f64],
    lda: usize,
    ipiv: &mut [usize],
    nb: usize,
) -> Result<()> {
    let mn = m.min(n);
    if nb <= 1 || nb >= mn {
        return dgetrf_unblocked(m, n, a, lda, ipiv);
    }
    let mut status = Ok(());
    let mut j = 0;
    while j < mn {
        let jb = nb.min(mn - j);
        // Factor the m-j × jb panel. Panel rows start at j; the panel is
        // interleaved with the rest, so pack it, factor, and write back.
        let pm = m - j;
        let mut panel = vec![0.0f64; pm * jb];
        for c in 0..jb {
            panel[c * pm..(c + 1) * pm]
                .copy_from_slice(&a[idx(j, j + c, lda)..idx(j, j + c, lda) + pm]);
        }
        let mut piv = vec![0usize; jb.min(pm)];
        if let Err(e) = dgetrf_unblocked(pm, jb, &mut panel, pm, &mut piv) {
            if status.is_ok() {
                status = Err(match e {
                    LinalgError::Singular(i) => LinalgError::Singular(i + j),
                    other => other,
                });
            }
        }
        for c in 0..jb {
            a[idx(j, j + c, lda)..idx(j, j + c, lda) + pm]
                .copy_from_slice(&panel[c * pm..(c + 1) * pm]);
        }
        // Record pivots (global indices) and apply to the *other* columns.
        for (k, &p) in piv.iter().enumerate() {
            ipiv[j + k] = p + j;
        }
        // apply interchanges to columns [0, j) and [j+jb, n)
        for k in j..j + piv.len() {
            let p = ipiv[k];
            if p != k {
                for col in (0..j).chain(j + jb..n) {
                    a.swap(idx(k, col, lda), idx(p, col, lda));
                }
            }
        }
        if j + jb < n {
            // U12 := L11⁻¹ A12
            let ncols = n - j - jb;
            // Copy A12 block? dtrsm operates in place on the submatrix
            // starting at (j, j+jb); the diagonal block L11 is at (j,j).
            // Submatrix views via offsets share the buffer with A but
            // dtrsm only reads the L11 block and writes A12 — pack L11
            // to satisfy the borrow checker.
            let mut l11 = vec![0.0f64; jb * jb];
            for c in 0..jb {
                l11[c * jb..(c + 1) * jb]
                    .copy_from_slice(&a[idx(j, j + c, lda)..idx(j, j + c, lda) + jb]);
            }
            dtrsm(
                Side::Left, Uplo::Lower, Trans::No, Diag::Unit, jb, ncols, 1.0,
                &l11, jb, &mut a[idx(j, j + jb, lda)..], lda,
            );
            if j + jb < m {
                // A22 -= L21 · U12
                let mrem = m - j - jb;
                // pack L21 (mrem×jb) and U12 (jb×ncols)
                let mut l21 = vec![0.0f64; mrem * jb];
                for c in 0..jb {
                    l21[c * mrem..(c + 1) * mrem].copy_from_slice(
                        &a[idx(j + jb, j + c, lda)..idx(j + jb, j + c, lda) + mrem],
                    );
                }
                let mut u12 = vec![0.0f64; jb * ncols];
                for c in 0..ncols {
                    u12[c * jb..(c + 1) * jb].copy_from_slice(
                        &a[idx(j, j + jb + c, lda)..idx(j, j + jb + c, lda) + jb],
                    );
                }
                dgemm(
                    Trans::No, Trans::No, mrem, ncols, jb, -1.0, &l21, mrem, &u12, jb,
                    1.0, &mut a[idx(j + jb, j + jb, lda)..], lda,
                );
            }
        }
        j += jb;
    }
    status
}

/// Solve op(A)·X = B given the dgetrf factorization (LAPACK dgetrs).
pub fn dgetrs(
    trans: Trans,
    n: usize,
    nrhs: usize,
    a: &[f64],
    lda: usize,
    ipiv: &[usize],
    b: &mut [f64],
    ldb: usize,
) {
    match trans {
        Trans::No => {
            dlaswp(nrhs, b, ldb, 0, n, ipiv);
            dtrsm(Side::Left, Uplo::Lower, Trans::No, Diag::Unit, n, nrhs, 1.0, a, lda, b, ldb);
            dtrsm(Side::Left, Uplo::Upper, Trans::No, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
        }
        Trans::Yes => {
            dtrsm(Side::Left, Uplo::Upper, Trans::Yes, Diag::NonUnit, n, nrhs, 1.0, a, lda, b, ldb);
            dtrsm(Side::Left, Uplo::Lower, Trans::Yes, Diag::Unit, n, nrhs, 1.0, a, lda, b, ldb);
            // reverse the interchanges (element-wise: rows are strided)
            for i in (0..n).rev() {
                let p = ipiv[i];
                if p != i {
                    for col in 0..nrhs {
                        b.swap(i + col * ldb, p + col * ldb);
                    }
                }
            }
        }
    }
}

/// Solve A·X = B by LU with partial pivoting (LAPACK dgesv).
/// A is overwritten with its factorization, B with the solution.
pub fn dgesv(
    n: usize,
    nrhs: usize,
    a: &mut [f64],
    lda: usize,
    ipiv: &mut [usize],
    b: &mut [f64],
    ldb: usize,
) -> Result<()> {
    dgetrf(n, n, a, lda, ipiv)?;
    dgetrs(Trans::No, n, nrhs, a, lda, ipiv, b, ldb);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    fn reconstruct_lu(a: &Matrix, ipiv: &[usize], m: usize, n: usize) -> Matrix {
        // P·A = L·U  ⇒  A = Pᵀ L U; rebuild L·U then un-apply swaps.
        let mn = m.min(n);
        let mut l = Matrix::zeros(m, mn);
        let mut u = Matrix::zeros(mn, n);
        for j in 0..mn {
            l[(j, j)] = 1.0;
            for i in j + 1..m {
                l[(i, j)] = a[(i, j)];
            }
        }
        for j in 0..n {
            for i in 0..mn.min(j + 1) {
                u[(i, j)] = a[(i, j)];
            }
        }
        let mut lu = l.matmul(&u);
        // apply swaps in reverse to recover original row order
        for i in (0..mn).rev() {
            let p = ipiv[i];
            if p != i {
                for col in 0..n {
                    let t = lu[(i, col)];
                    lu[(i, col)] = lu[(p, col)];
                    lu[(p, col)] = t;
                }
            }
        }
        lu
    }

    #[test]
    fn getrf_unblocked_reconstructs() {
        let mut rng = Xoshiro256::seeded(30);
        for &(m, n) in &[(6usize, 6usize), (8, 5), (5, 8)] {
            let a0 = Matrix::random(m, n, &mut rng);
            let mut a = a0.clone();
            let mut ipiv = vec![0usize; m.min(n)];
            dgetrf_unblocked(m, n, &mut a.data, m, &mut ipiv).unwrap();
            let lu = reconstruct_lu(&a, &ipiv, m, n);
            assert!(lu.max_abs_diff(&a0) < 1e-12, "m={m} n={n}");
        }
    }

    #[test]
    fn getrf_blocked_matches_unblocked() {
        let mut rng = Xoshiro256::seeded(31);
        let n = 37; // not a multiple of nb
        let a0 = Matrix::random(n, n, &mut rng);
        let mut a_u = a0.clone();
        let mut piv_u = vec![0usize; n];
        dgetrf_unblocked(n, n, &mut a_u.data, n, &mut piv_u).unwrap();
        let mut a_b = a0.clone();
        let mut piv_b = vec![0usize; n];
        dgetrf_nb(n, n, &mut a_b.data, n, &mut piv_b, 8).unwrap();
        assert_eq!(piv_u, piv_b);
        assert!(a_u.max_abs_diff(&a_b) < 1e-11);
    }

    #[test]
    fn gesv_solves() {
        let mut rng = Xoshiro256::seeded(32);
        let n = 50;
        let nrhs = 7;
        let a0 = Matrix::random_spd(n, &mut rng); // well conditioned
        let x = Matrix::random(n, nrhs, &mut rng);
        let b0 = a0.matmul(&x);
        let mut a = a0.clone();
        let mut b = b0.clone();
        let mut ipiv = vec![0usize; n];
        dgesv(n, nrhs, &mut a.data, n, &mut ipiv, &mut b.data, n).unwrap();
        assert!(b.max_abs_diff(&x) < 1e-9);
    }

    #[test]
    fn getrs_transpose_solves() {
        let mut rng = Xoshiro256::seeded(33);
        let n = 20;
        let a0 = Matrix::random_spd(n, &mut rng);
        let x = Matrix::random(n, 3, &mut rng);
        let b0 = a0.transpose().matmul(&x);
        let mut a = a0.clone();
        let mut ipiv = vec![0usize; n];
        dgetrf(n, n, &mut a.data, n, &mut ipiv).unwrap();
        let mut b = b0.clone();
        dgetrs(Trans::Yes, n, 3, &a.data, n, &ipiv, &mut b.data, n);
        assert!(b.max_abs_diff(&x) < 1e-9);
    }

    #[test]
    fn singular_matrix_reported() {
        // column of zeros ⇒ singular
        let mut a = Matrix::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = 1.0;
        // column 2 all zero
        let mut ipiv = vec![0usize; 3];
        let err = dgetrf_unblocked(3, 3, &mut a.data, 3, &mut ipiv).unwrap_err();
        assert_eq!(err, LinalgError::Singular(2));
    }

    #[test]
    fn laswp_applies_swaps() {
        // 3×2 matrix, swap row 0 with row 2.
        let mut a = Matrix::from_fn(3, 2, |i, j| (i * 10 + j) as f64);
        dlaswp(2, &mut a.data, 3, 0, 1, &[2]);
        assert_eq!(a[(0, 0)], 20.0);
        assert_eq!(a[(2, 0)], 0.0);
        assert_eq!(a[(0, 1)], 21.0);
    }
}
