//! Triangular matrix inversion: unblocked dtrti2 and the blocked
//! algorithm of the paper's §2.5 (Experiment 7 / Fig. 6), which
//! traverses the matrix in steps of a block size `nb` using dtrmm,
//! dtrsm and dtrti2 — the algorithm whose block size the paper tunes.

use crate::linalg::blas3::{dtrmm, dtrsm};
use crate::linalg::{Diag, LinalgError, Result, Side, Trans, Uplo};

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

/// Unblocked triangular inversion in place (LAPACK dtrti2).
pub fn dtrti2(uplo: Uplo, diag: Diag, n: usize, a: &mut [f64], lda: usize) -> Result<()> {
    match uplo {
        Uplo::Lower => {
            for j in (0..n).rev() {
                let ajj = if diag == Diag::NonUnit {
                    let d = a[idx(j, j, lda)];
                    if d == 0.0 {
                        return Err(LinalgError::Singular(j));
                    }
                    a[idx(j, j, lda)] = 1.0 / d;
                    -1.0 / d
                } else {
                    -1.0
                };
                // column j below the diagonal: x := L22·x with the
                // already-inverted trailing block (in-place trmv —
                // iterate bottom-up so unread entries stay original)
                for i in (j + 1..n).rev() {
                    let mut s = a[idx(i, j, lda)]
                        * if diag == Diag::NonUnit { a[idx(i, i, lda)] } else { 1.0 };
                    for k in j + 1..i {
                        s += a[idx(i, k, lda)] * a[idx(k, j, lda)];
                    }
                    a[idx(i, j, lda)] = s;
                }
                for i in j + 1..n {
                    a[idx(i, j, lda)] *= ajj;
                }
            }
        }
        Uplo::Upper => {
            for j in 0..n {
                let ajj = if diag == Diag::NonUnit {
                    let d = a[idx(j, j, lda)];
                    if d == 0.0 {
                        return Err(LinalgError::Singular(j));
                    }
                    a[idx(j, j, lda)] = 1.0 / d;
                    -1.0 / d
                } else {
                    -1.0
                };
                // column j above the diagonal: x := U00·x (in-place
                // trmv — iterate top-down so unread entries stay
                // original: x_i depends only on x_k with k > i)
                for i in 0..j {
                    let mut s = a[idx(i, j, lda)]
                        * if diag == Diag::NonUnit { a[idx(i, i, lda)] } else { 1.0 };
                    for k in i + 1..j {
                        s += a[idx(i, k, lda)] * a[idx(k, j, lda)];
                    }
                    a[idx(i, j, lda)] = s;
                }
                for i in 0..j {
                    a[idx(i, j, lda)] *= ajj;
                }
            }
        }
    }
    Ok(())
}

/// Blocked triangular inversion with block size `nb` (the paper's
/// Experiment 7 algorithm; LAPACK dtrtri uses the same structure).
///
/// For Lower: for each diagonal block step `j` (forward),
///   A[j+jb.., j..j+jb] := -A[j+jb.., j+jb..]⁻¹-free update:
///     A21 := A21 · A11⁻¹ after A21 := -A22⁻¹…  — we use the standard
/// LAPACK ordering: A21 := -A22_current · A21 · A11⁻¹ via dtrmm + dtrsm,
/// then invert A11 in place with dtrti2.
pub fn dtrtri_blocked(
    uplo: Uplo,
    diag: Diag,
    n: usize,
    a: &mut [f64],
    lda: usize,
    nb: usize,
) -> Result<()> {
    if nb <= 1 || nb >= n {
        return dtrti2(uplo, diag, n, a, lda);
    }
    match uplo {
        Uplo::Upper => {
            // LAPACK dtrtri 'U': forward over column blocks
            let mut j = 0;
            while j < n {
                let jb = nb.min(n - j);
                if j > 0 {
                    // A01 := A00_inv · A01  (A00 already inverted)
                    // pack inverted leading block A00 (j×j upper)
                    let mut a00 = vec![0.0f64; j * j];
                    for c in 0..j {
                        a00[c * j..(c + 1) * j]
                            .copy_from_slice(&a[idx(0, c, lda)..idx(0, c, lda) + j]);
                    }
                    dtrmm(
                        Side::Left, Uplo::Upper, Trans::No, diag, j, jb, 1.0, &a00, j,
                        &mut a[idx(0, j, lda)..], lda,
                    );
                    // A01 := -A01 · A11⁻¹
                    let mut a11 = vec![0.0f64; jb * jb];
                    for c in 0..jb {
                        a11[c * jb..(c + 1) * jb]
                            .copy_from_slice(&a[idx(j, j + c, lda)..idx(j, j + c, lda) + jb]);
                    }
                    dtrsm(
                        Side::Right, Uplo::Upper, Trans::No, diag, j, jb, -1.0, &a11, jb,
                        &mut a[idx(0, j, lda)..], lda,
                    );
                }
                dtrti2(uplo, diag, jb, &mut a[idx(j, j, lda)..], lda)
                    .map_err(|e| shift_singular(e, j))?;
                j += jb;
            }
        }
        Uplo::Lower => {
            // LAPACK dtrtri 'L': backward over column blocks
            let nn = n.div_ceil(nb);
            for blk in (0..nn).rev() {
                let j = blk * nb;
                let jb = nb.min(n - j);
                if j + jb < n {
                    let rem = n - j - jb;
                    // A21 := A22_inv · A21 (A22 already inverted)
                    let mut a22 = vec![0.0f64; rem * rem];
                    for c in 0..rem {
                        a22[c * rem..(c + 1) * rem].copy_from_slice(
                            &a[idx(j + jb, j + jb + c, lda)..idx(j + jb, j + jb + c, lda) + rem],
                        );
                    }
                    dtrmm(
                        Side::Left, Uplo::Lower, Trans::No, diag, rem, jb, 1.0, &a22, rem,
                        &mut a[idx(j + jb, j, lda)..], lda,
                    );
                    // A21 := -A21 · A11⁻¹
                    let mut a11 = vec![0.0f64; jb * jb];
                    for c in 0..jb {
                        a11[c * jb..(c + 1) * jb]
                            .copy_from_slice(&a[idx(j, j + c, lda)..idx(j, j + c, lda) + jb]);
                    }
                    dtrsm(
                        Side::Right, Uplo::Lower, Trans::No, diag, rem, jb, -1.0, &a11, jb,
                        &mut a[idx(j + jb, j, lda)..], lda,
                    );
                }
                dtrti2(uplo, diag, jb, &mut a[idx(j, j, lda)..], lda)
                    .map_err(|e| shift_singular(e, j))?;
            }
        }
    }
    Ok(())
}

fn shift_singular(e: LinalgError, j: usize) -> LinalgError {
    match e {
        LinalgError::Singular(i) => LinalgError::Singular(i + j),
        other => other,
    }
}

/// Default blocked inversion (LAPACK dtrtri with nb=64).
pub fn dtrtri(uplo: Uplo, diag: Diag, n: usize, a: &mut [f64], lda: usize) -> Result<()> {
    dtrtri_blocked(uplo, diag, n, a, lda, 64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    fn check_inverse(a0: &Matrix, inv: &Matrix, n: usize) {
        let prod = a0.matmul(inv);
        let eye = Matrix::identity(n);
        assert!(prod.max_abs_diff(&eye) < 1e-9, "diff {}", prod.max_abs_diff(&eye));
    }

    #[test]
    fn trti2_inverts_both_uplos() {
        let mut rng = Xoshiro256::seeded(50);
        for &uplo in &[Uplo::Lower, Uplo::Upper] {
            let n = 16;
            let a0 = Matrix::random_triangular(n, uplo, &mut rng);
            let mut a = a0.clone();
            dtrti2(uplo, Diag::NonUnit, n, &mut a.data, n).unwrap();
            check_inverse(&a0, &a, n);
        }
    }

    #[test]
    fn trtri_blocked_inverts() {
        let mut rng = Xoshiro256::seeded(51);
        for &uplo in &[Uplo::Lower, Uplo::Upper] {
            for &nb in &[2usize, 5, 8, 100] {
                let n = 23;
                let a0 = Matrix::random_triangular(n, uplo, &mut rng);
                let mut a = a0.clone();
                dtrtri_blocked(uplo, Diag::NonUnit, n, &mut a.data, n, nb).unwrap();
                check_inverse(&a0, &a, n);
            }
        }
    }

    #[test]
    fn trtri_unit_diag() {
        let mut rng = Xoshiro256::seeded(52);
        let n = 10;
        let mut a0 = Matrix::random_triangular(n, Uplo::Lower, &mut rng);
        for i in 0..n {
            a0[(i, i)] = 1.0;
        }
        let mut a = a0.clone();
        dtrtri_blocked(Uplo::Lower, Diag::Unit, n, &mut a.data, n, 4).unwrap();
        // rebuild with explicit unit diagonal
        let mut inv = a.clone();
        for i in 0..n {
            inv[(i, i)] = 1.0;
        }
        check_inverse(&a0, &inv, n);
    }

    #[test]
    fn singular_reported_with_global_index() {
        let mut a = Matrix::identity(8);
        a[(5, 5)] = 0.0;
        let err = dtrtri_blocked(Uplo::Lower, Diag::NonUnit, 8, &mut a.data, 8, 3).unwrap_err();
        assert_eq!(err, LinalgError::Singular(5));
    }
}
