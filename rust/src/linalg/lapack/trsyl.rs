//! Triangular Sylvester equation  A·X + isgn·X·B = scale·C  (we fix
//! scale = 1, isgn = +1), with A (m×m) and B (n×n) upper triangular —
//! the kernel of the paper's library-selection study (§4.2, Fig. 12).
//!
//! Three algorithmic variants mirror the libraries the paper compares:
//!
//! * [`dtrsyl_unblocked`] — element/column-wise backward-substitution
//!   (LAPACK's dtrsyl is unblocked; "reaches 2 Gflops/s … falls below
//!   1"),
//! * [`dtrsyl_blocked`]   — block partitioning with gemm updates
//!   (libFLAME's approach),
//! * [`dtrsyl_recursive`] — recursive splitting (RECSY's approach,
//!   which the paper finds fastest).
//!
//! Restriction vs LAPACK: A and B are strictly triangular (real Schur
//! quasi-triangular 2×2 bumps are not supported); callers must ensure
//! spectra of A and −B are disjoint or `CommonEigenvalues` is returned.

use crate::linalg::blas3::dgemm;
use crate::linalg::{LinalgError, Result, Trans};

#[inline(always)]
fn idx(i: usize, j: usize, ld: usize) -> usize {
    i + j * ld
}

const SMIN_FACTOR: f64 = 1e-12;

/// Element-wise backward/forward substitution — faithful to LAPACK's
/// netlib dtrsyl (trana='N', tranb='N'), which solves one 1×1 (dlasy2)
/// system per element with two inner products, one of them a strided
/// row-dot. This is the "unblocked reference library" variant: level-1
/// BLAS bound, cache-hostile for large n, exactly like the LAPACK and
/// MKL curves in the paper's Fig. 12.
pub fn dtrsyl_unblocked(
    m: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
) -> Result<()> {
    // X[i,j] = (C[i,j] − Σ_{k>i} A[i,k]·X[k,j] − Σ_{l<j} X[i,l]·B[l,j])
    //          / (A[i,i] + B[j,j])
    for j in 0..n {
        let bjj = b[idx(j, j, ldb)];
        for i in (0..m).rev() {
            // column-dot over A's row i (strided in A)
            let mut s1 = 0.0;
            for k in i + 1..m {
                s1 += a[idx(i, k, lda)] * c[idx(k, j, ldc)];
            }
            // row-dot over X's row i (strided in C) — the LAPACK ddot
            let mut s2 = 0.0;
            for l in 0..j {
                s2 += c[idx(i, l, ldc)] * b[idx(l, j, ldb)];
            }
            let diag = a[idx(i, i, lda)] + bjj;
            if diag.abs() < SMIN_FACTOR {
                return Err(LinalgError::CommonEigenvalues(i));
            }
            c[idx(i, j, ldc)] = (c[idx(i, j, ldc)] - s1 - s2) / diag;
        }
    }
    Ok(())
}

/// Blocked variant: partition X into mb×nb tiles; solve diagonal-path
/// subproblems unblocked and update with dgemm (libFLAME-style).
pub fn dtrsyl_blocked(
    m: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
    mb: usize,
    nb: usize,
) -> Result<()> {
    let mb = mb.max(1);
    let nb = nb.max(1);
    // Row blocks of A from bottom to top; column blocks of B left to
    // right. For block (I, J):
    //   A_II·X_IJ + X_IJ·B_JJ = C_IJ − Σ_{K>I} A_IK·X_KJ − Σ_{L<J} X_IL·B_LJ
    let row_starts: Vec<usize> = (0..m).step_by(mb).collect();
    let col_starts: Vec<usize> = (0..n).step_by(nb).collect();
    for &j0 in &col_starts {
        let jb = nb.min(n - j0);
        // Horizontal update with all solved column-blocks L < J:
        //   C[:, J] -= X[:, L] · B[L, J]
        if j0 > 0 {
            // pack X[:, 0..j0] (m×j0) and B[0..j0, j0..j0+jb]
            let mut xl = vec![0.0f64; m * j0];
            for cix in 0..j0 {
                xl[cix * m..(cix + 1) * m]
                    .copy_from_slice(&c[idx(0, cix, ldc)..idx(0, cix, ldc) + m]);
            }
            let mut blj = vec![0.0f64; j0 * jb];
            for cix in 0..jb {
                blj[cix * j0..(cix + 1) * j0]
                    .copy_from_slice(&b[idx(0, j0 + cix, ldb)..idx(0, j0 + cix, ldb) + j0]);
            }
            dgemm(
                Trans::No, Trans::No, m, jb, j0, -1.0, &xl, m, &blj, j0, 1.0,
                &mut c[idx(0, j0, ldc)..], ldc,
            );
        }
        for &i0 in row_starts.iter().rev() {
            let ib = mb.min(m - i0);
            // Vertical update with solved row-blocks K > I:
            //   C[I, J] -= A[I, K] · X[K, J]
            if i0 + ib < m {
                let krows = m - i0 - ib;
                let mut aik = vec![0.0f64; ib * krows];
                for cix in 0..krows {
                    aik[cix * ib..(cix + 1) * ib].copy_from_slice(
                        &a[idx(i0, i0 + ib + cix, lda)..idx(i0, i0 + ib + cix, lda) + ib],
                    );
                }
                let mut xkj = vec![0.0f64; krows * jb];
                for cix in 0..jb {
                    xkj[cix * krows..(cix + 1) * krows].copy_from_slice(
                        &c[idx(i0 + ib, j0 + cix, ldc)..idx(i0 + ib, j0 + cix, ldc) + krows],
                    );
                }
                let mut upd = vec![0.0f64; ib * jb];
                dgemm(
                    Trans::No, Trans::No, ib, jb, krows, 1.0, &aik, ib, &xkj, krows, 0.0,
                    &mut upd, ib,
                );
                for cix in 0..jb {
                    for r in 0..ib {
                        c[idx(i0 + r, j0 + cix, ldc)] -= upd[r + cix * ib];
                    }
                }
            }
            // Solve the (ib × jb) diagonal subproblem unblocked. Pack
            // the diagonal blocks of A and B.
            let mut aii = vec![0.0f64; ib * ib];
            for cix in 0..ib {
                aii[cix * ib..(cix + 1) * ib]
                    .copy_from_slice(&a[idx(i0, i0 + cix, lda)..idx(i0, i0 + cix, lda) + ib]);
            }
            let mut bjj = vec![0.0f64; jb * jb];
            for cix in 0..jb {
                bjj[cix * jb..(cix + 1) * jb]
                    .copy_from_slice(&b[idx(j0, j0 + cix, ldb)..idx(j0, j0 + cix, ldb) + jb]);
            }
            let mut cij = vec![0.0f64; ib * jb];
            for cix in 0..jb {
                cij[cix * ib..(cix + 1) * ib].copy_from_slice(
                    &c[idx(i0, j0 + cix, ldc)..idx(i0, j0 + cix, ldc) + ib],
                );
            }
            trsyl_base(ib, jb, &aii, ib, &bjj, jb, &mut cij, ib)
                .map_err(|e| shift_common(e, i0))?;
            for cix in 0..jb {
                c[idx(i0, j0 + cix, ldc)..idx(i0, j0 + cix, ldc) + ib]
                    .copy_from_slice(&cij[cix * ib..(cix + 1) * ib]);
            }
        }
    }
    Ok(())
}

fn shift_common(e: LinalgError, off: usize) -> LinalgError {
    match e {
        LinalgError::CommonEigenvalues(i) => LinalgError::CommonEigenvalues(i + off),
        other => other,
    }
}

const REC_BASE: usize = 64;

/// Block solver used at the recursion base: one column sweep with a
/// fused update (level-2.5; RECSY's small-problem kernel analog).
fn trsyl_base(
    m: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
) -> Result<()> {
    // column sweep: (A + b_jj I) x_j = c_j − X[:,<j]·B[<j,j]
    for j in 0..n {
        let bjj = b[idx(j, j, ldb)];
        for k in 0..j {
            let bkj = b[idx(k, j, ldb)];
            if bkj != 0.0 {
                for i in 0..m {
                    let xki = c[idx(i, k, ldc)];
                    c[idx(i, j, ldc)] -= xki * bkj;
                }
            }
        }
        for i in (0..m).rev() {
            let mut s = c[idx(i, j, ldc)];
            for k in i + 1..m {
                s -= a[idx(i, k, lda)] * c[idx(k, j, ldc)];
            }
            let diag = a[idx(i, i, lda)] + bjj;
            if diag.abs() < SMIN_FACTOR {
                return Err(LinalgError::CommonEigenvalues(i));
            }
            c[idx(i, j, ldc)] = s / diag;
        }
    }
    Ok(())
}

/// Recursive variant (RECSY-style): split the larger dimension in
/// half, solve recursively, update with one gemm. Submatrices are
/// views (offset + leading dimension); the only pack is the X₂ row
/// panel needed to satisfy Rust aliasing in the m-split update.
pub fn dtrsyl_recursive(
    m: usize,
    n: usize,
    a: &[f64],
    lda: usize,
    b: &[f64],
    ldb: usize,
    c: &mut [f64],
    ldc: usize,
) -> Result<()> {
    if m.max(n) <= REC_BASE {
        return trsyl_base(m, n, a, lda, b, ldb, c, ldc);
    }
    if m >= n {
        // split A = [[A11, A12], [0, A22]], rows of X/C likewise.
        let m1 = m / 2;
        let m2 = m - m1;
        // bottom rows first: A22·X2 + X2·B = C2 (views at row offset)
        dtrsyl_recursive(m2, n, &a[idx(m1, m1, lda)..], lda, b, ldb, &mut c[m1..], ldc)
            .map_err(|e| shift_common(e, m1))?;
        // C1 -= A12 · X2 — X2's rows interleave with C1's in memory,
        // so pack the solved row panel once.
        let mut x2 = vec![0.0f64; m2 * n];
        for j in 0..n {
            x2[j * m2..(j + 1) * m2]
                .copy_from_slice(&c[idx(m1, j, ldc)..idx(m1, j, ldc) + m2]);
        }
        dgemm(
            Trans::No, Trans::No, m1, n, m2, -1.0, &a[idx(0, m1, lda)..], lda, &x2, m2,
            1.0, c, ldc,
        );
        // A11·X1 + X1·B = C1
        dtrsyl_recursive(m1, n, a, lda, b, ldb, c, ldc)
    } else {
        // split B = [[B11, B12], [0, B22]], columns of X/C likewise.
        let n1 = n / 2;
        let n2 = n - n1;
        // left columns first: A·X1 + X1·B11 = C1
        dtrsyl_recursive(m, n1, a, lda, b, ldb, c, ldc)?;
        // C2 -= X1 · B12 — column split is contiguous, no packing
        let (c1, c2) = c.split_at_mut(n1 * ldc);
        dgemm(
            Trans::No, Trans::No, m, n2, n1, -1.0, c1, ldc, &b[idx(0, n1, ldb)..], ldb,
            1.0, c2, ldc,
        );
        // A·X2 + X2·B22 = C2 (view at column offset)
        dtrsyl_recursive(m, n2, a, lda, &b[idx(n1, n1, ldb)..], ldb, c2, ldc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::Uplo;
    use crate::util::rng::Xoshiro256;

    /// Build a well-posed problem: A upper-tri with diag in ]1,2[,
    /// B upper-tri with diag in ]1,2[ ⇒ A + b_jj I never singular.
    fn make_problem(m: usize, n: usize, seed: u64) -> (Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Xoshiro256::seeded(seed);
        let a = Matrix::random_triangular(m, Uplo::Upper, &mut rng);
        let b = Matrix::random_triangular(n, Uplo::Upper, &mut rng);
        let x = Matrix::random(m, n, &mut rng);
        // C = A X + X B
        let c = {
            let ax = a.matmul(&x);
            let xb = x.matmul(&b);
            Matrix::from_fn(m, n, |i, j| ax[(i, j)] + xb[(i, j)])
        };
        (a, b, x, c)
    }

    #[test]
    fn unblocked_recovers_x() {
        let (a, b, x, c) = make_problem(12, 9, 80);
        let mut sol = c.clone();
        dtrsyl_unblocked(12, 9, &a.data, 12, &b.data, 9, &mut sol.data, 12).unwrap();
        assert!(sol.max_abs_diff(&x) < 1e-9);
    }

    #[test]
    fn blocked_recovers_x() {
        for &(mb, nb) in &[(4usize, 3usize), (5, 5), (100, 100)] {
            let (a, b, x, c) = make_problem(17, 13, 81);
            let mut sol = c.clone();
            dtrsyl_blocked(17, 13, &a.data, 17, &b.data, 13, &mut sol.data, 17, mb, nb)
                .unwrap();
            assert!(sol.max_abs_diff(&x) < 1e-9, "mb={mb} nb={nb}");
        }
    }

    #[test]
    fn recursive_recovers_x() {
        let (a, b, x, c) = make_problem(70, 50, 82);
        let mut sol = c.clone();
        dtrsyl_recursive(70, 50, &a.data, 70, &b.data, 50, &mut sol.data, 70).unwrap();
        assert!(sol.max_abs_diff(&x) < 1e-8);
    }

    #[test]
    fn variants_agree() {
        let (a, b, _x, c) = make_problem(40, 40, 83);
        let mut s1 = c.clone();
        dtrsyl_unblocked(40, 40, &a.data, 40, &b.data, 40, &mut s1.data, 40).unwrap();
        let mut s2 = c.clone();
        dtrsyl_blocked(40, 40, &a.data, 40, &b.data, 40, &mut s2.data, 40, 8, 8).unwrap();
        let mut s3 = c.clone();
        dtrsyl_recursive(40, 40, &a.data, 40, &b.data, 40, &mut s3.data, 40).unwrap();
        assert!(s1.max_abs_diff(&s2) < 1e-10);
        assert!(s1.max_abs_diff(&s3) < 1e-10);
    }

    #[test]
    fn common_eigenvalues_detected() {
        // a_00 = 1, b_00 = -1 ⇒ a_00 + b_00 = 0
        let a = Matrix::identity(2);
        let mut b = Matrix::identity(2);
        b[(0, 0)] = -1.0;
        b[(1, 1)] = -1.0;
        let mut c = Matrix::random(2, 2, &mut Xoshiro256::seeded(84));
        let err = dtrsyl_unblocked(2, 2, &a.data, 2, &b.data, 2, &mut c.data, 2).unwrap_err();
        assert!(matches!(err, LinalgError::CommonEigenvalues(_)));
    }

    #[test]
    fn rectangular_shapes() {
        for &(m, n) in &[(1usize, 8usize), (8, 1), (33, 7), (7, 33)] {
            let (a, b, x, c) = make_problem(m, n, 85 + (m * 100 + n) as u64);
            let mut sol = c.clone();
            dtrsyl_recursive(m, n, &a.data, m, &b.data, n, &mut sol.data, m).unwrap();
            assert!(sol.max_abs_diff(&x) < 1e-8, "m={m} n={n}");
        }
    }
}
