//! LAPACK-level routines built on the BLAS layers: factorizations,
//! solvers, eigensolvers (four symmetric drivers, as compared in the
//! paper's Fig. 5), triangular inversion (the paper's block-size study,
//! Fig. 6) and the triangular Sylvester equation in three algorithmic
//! variants (the paper's library study, Fig. 12).

pub mod getrf;
pub mod potrf;
pub mod trtri;
pub mod tridiag;
pub mod eig;
pub mod trsyl;

pub use getrf::{dgesv, dgetrf, dgetrf_unblocked, dgetrs, dlaswp};
pub use potrf::{dposv, dpotrf, dpotrf_unblocked, dpotrs};
pub use trtri::{dtrti2, dtrtri, dtrtri_blocked};
pub use tridiag::{dorgtr, dsytrd};
pub use eig::{dsyev, dsyevd, dsyevr, dsyevx, EigResult};
pub use trsyl::{dtrsyl_blocked, dtrsyl_recursive, dtrsyl_unblocked};
