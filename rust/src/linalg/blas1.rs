//! Level-1 BLAS: vector-vector operations (stride-1 and strided).

/// y := alpha*x + y
pub fn daxpy(n: usize, alpha: f64, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
    if alpha == 0.0 || n == 0 {
        return;
    }
    if incx == 1 && incy == 1 {
        for i in 0..n {
            y[i] += alpha * x[i];
        }
    } else {
        for i in 0..n {
            y[i * incy] += alpha * x[i * incx];
        }
    }
}

/// dot := xᵀy
pub fn ddot(n: usize, x: &[f64], incx: usize, y: &[f64], incy: usize) -> f64 {
    let mut s = 0.0;
    if incx == 1 && incy == 1 {
        for i in 0..n {
            s += x[i] * y[i];
        }
    } else {
        for i in 0..n {
            s += x[i * incx] * y[i * incy];
        }
    }
    s
}

/// x := alpha*x
pub fn dscal(n: usize, alpha: f64, x: &mut [f64], incx: usize) {
    for i in 0..n {
        x[i * incx] *= alpha;
    }
}

/// y := x
pub fn dcopy(n: usize, x: &[f64], incx: usize, y: &mut [f64], incy: usize) {
    for i in 0..n {
        y[i * incy] = x[i * incx];
    }
}

/// Swap x and y.
pub fn dswap(n: usize, x: &mut [f64], incx: usize, y: &mut [f64], incy: usize) {
    for i in 0..n {
        std::mem::swap(&mut x[i * incx], &mut y[i * incy]);
    }
}

/// Euclidean norm, with scaling against overflow (LAPACK dnrm2 style).
pub fn dnrm2(n: usize, x: &[f64], incx: usize) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 1.0f64;
    for i in 0..n {
        let xi = x[i * incx];
        if xi != 0.0 {
            let absxi = xi.abs();
            if scale < absxi {
                ssq = 1.0 + ssq * (scale / absxi).powi(2);
                scale = absxi;
            } else {
                ssq += (absxi / scale).powi(2);
            }
        }
    }
    scale * ssq.sqrt()
}

/// Sum of absolute values.
pub fn dasum(n: usize, x: &[f64], incx: usize) -> f64 {
    (0..n).map(|i| x[i * incx].abs()).sum()
}

/// Index of the element with maximum absolute value (0-based).
pub fn idamax(n: usize, x: &[f64], incx: usize) -> usize {
    let mut best = 0;
    let mut bv = 0.0;
    for i in 0..n {
        let v = x[i * incx].abs();
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        daxpy(3, 2.0, &x, 1, &mut y, 1);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn axpy_strided() {
        let x = [1.0, 0.0, 2.0, 0.0];
        let mut y = [0.0; 6];
        daxpy(2, 1.0, &x, 2, &mut y, 3);
        assert_eq!(y, [1.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn dot_and_norm() {
        let x = [3.0, 4.0];
        assert_eq!(ddot(2, &x, 1, &x, 1), 25.0);
        assert!((dnrm2(2, &x, 1) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn nrm2_no_overflow() {
        let x = [1e200, 1e200];
        let n = dnrm2(2, &x, 1);
        assert!((n - 2.0f64.sqrt() * 1e200).abs() / n < 1e-14);
    }

    #[test]
    fn scal_copy_swap() {
        let mut x = [1.0, 2.0];
        dscal(2, 3.0, &mut x, 1);
        assert_eq!(x, [3.0, 6.0]);
        let mut y = [0.0; 2];
        dcopy(2, &x, 1, &mut y, 1);
        assert_eq!(y, x);
        let mut z = [7.0, 8.0];
        dswap(2, &mut x, 1, &mut z, 1);
        assert_eq!(x, [7.0, 8.0]);
        assert_eq!(z, [3.0, 6.0]);
    }

    #[test]
    fn iamax_and_asum() {
        let x = [1.0, -5.0, 3.0];
        assert_eq!(idamax(3, &x, 1), 1);
        assert_eq!(dasum(3, &x, 1), 9.0);
    }
}
