//! Owned column-major matrices and helpers used by tests, examples and
//! the sampler's utility kernels.

use crate::util::rng::Xoshiro256;

/// An owned, dense, column-major `m×n` matrix of f64 with `ld == m`.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub m: usize,
    pub n: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(m: usize, n: usize) -> Matrix {
        Matrix { m, n, data: vec![0.0; m * n] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = 1.0;
        }
        a
    }

    /// Build from a function of (row, col).
    pub fn from_fn(m: usize, n: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut a = Matrix::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                a[(i, j)] = f(i, j);
            }
        }
        a
    }

    /// Random entries uniform in ]0,1[ (like the sampler's `dgerand`).
    pub fn random(m: usize, n: usize, rng: &mut Xoshiro256) -> Matrix {
        Matrix::from_fn(m, n, |_, _| rng.next_open01())
    }

    /// Random symmetric positive definite matrix: A = RᵀR + n·I
    /// (like the sampler's `dporand`).
    pub fn random_spd(n: usize, rng: &mut Xoshiro256) -> Matrix {
        let r = Matrix::random(n, n, rng);
        let mut a = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += r[(k, i)] * r[(k, j)];
                }
                a[(i, j)] = s;
            }
            a[(j, j)] += n as f64;
        }
        a
    }

    /// Random lower/upper triangular with a well-conditioned diagonal.
    pub fn random_triangular(
        n: usize,
        uplo: super::Uplo,
        rng: &mut Xoshiro256,
    ) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                let keep = match uplo {
                    super::Uplo::Lower => i >= j,
                    super::Uplo::Upper => i <= j,
                };
                if keep {
                    a[(i, j)] = rng.next_open01() - 0.5;
                }
            }
            a[(j, j)] = 1.0 + rng.next_open01(); // diag in ]1,2[
        }
        a
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.n, self.m, |i, j| self[(j, i)])
    }

    /// Naive reference matmul (for verifying the optimized paths).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.m);
        let mut c = Matrix::zeros(self.m, other.n);
        for j in 0..other.n {
            for k in 0..self.n {
                let bkj = other[(k, j)];
                for i in 0..self.m {
                    c[(i, j)] += self[(i, k)] * bkj;
                }
            }
        }
        c
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.m, self.n), (other.m, other.n));
        Matrix::from_fn(self.m, self.n, |i, j| self[(i, j)] - other[(i, j)])
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.m, self.n), (other.m, other.n));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.m..(j + 1) * self.m]
    }

    /// Leading dimension of the owned storage (== m).
    pub fn ld(&self) -> usize {
        self.m
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.m && j < self.n);
        &self.data[i + j * self.m]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.m && j < self.n);
        &mut self.data[i + j * self.m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Uplo;

    #[test]
    fn identity_matmul() {
        let mut rng = Xoshiro256::seeded(1);
        let a = Matrix::random(4, 6, &mut rng);
        let i4 = Matrix::identity(4);
        assert!(i4.matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::seeded(2);
        let a = Matrix::random(5, 3, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn spd_is_symmetric_and_diag_dominant() {
        let mut rng = Xoshiro256::seeded(3);
        let a = Matrix::random_spd(8, &mut rng);
        for i in 0..8 {
            for j in 0..8 {
                assert!((a[(i, j)] - a[(j, i)]).abs() < 1e-12);
            }
            assert!(a[(i, i)] > 8.0);
        }
    }

    #[test]
    fn triangular_structure() {
        let mut rng = Xoshiro256::seeded(4);
        let l = Matrix::random_triangular(6, Uplo::Lower, &mut rng);
        let u = Matrix::random_triangular(6, Uplo::Upper, &mut rng);
        for i in 0..6 {
            for j in 0..6 {
                if i < j {
                    assert_eq!(l[(i, j)], 0.0);
                }
                if i > j {
                    assert_eq!(u[(i, j)], 0.0);
                }
            }
            assert!(l[(i, i)] >= 1.0 && u[(i, i)] >= 1.0);
        }
    }

    #[test]
    fn frobenius_of_identity() {
        assert!((Matrix::identity(9).frobenius() - 3.0).abs() < 1e-15);
    }
}
