//! Level-2 BLAS: matrix-vector operations (column-major, with `ld`).

use super::{Diag, Trans, Uplo};

/// y := alpha*op(A)*x + beta*y where A is m×n.
pub fn dgemv(
    trans: Trans,
    m: usize,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    x: &[f64],
    incx: usize,
    beta: f64,
    y: &mut [f64],
    incy: usize,
) {
    let leny = match trans {
        Trans::No => m,
        Trans::Yes => n,
    };
    if beta != 1.0 {
        for i in 0..leny {
            y[i * incy] *= beta;
        }
    }
    if alpha == 0.0 {
        return;
    }
    match trans {
        Trans::No => {
            // y += alpha * A x — column sweep keeps A accesses contiguous
            for j in 0..n {
                let t = alpha * x[j * incx];
                if t != 0.0 {
                    let col = &a[j * lda..j * lda + m];
                    for i in 0..m {
                        y[i * incy] += t * col[i];
                    }
                }
            }
        }
        Trans::Yes => {
            for j in 0..n {
                let col = &a[j * lda..j * lda + m];
                let mut s = 0.0;
                for i in 0..m {
                    s += col[i] * x[i * incx];
                }
                y[j * incy] += alpha * s;
            }
        }
    }
}

/// A := alpha*x*yᵀ + A where A is m×n.
pub fn dger(
    m: usize,
    n: usize,
    alpha: f64,
    x: &[f64],
    incx: usize,
    y: &[f64],
    incy: usize,
    a: &mut [f64],
    lda: usize,
) {
    for j in 0..n {
        let t = alpha * y[j * incy];
        if t != 0.0 {
            let col = &mut a[j * lda..j * lda + m];
            for i in 0..m {
                col[i] += t * x[i * incx];
            }
        }
    }
}

/// Solve op(A) x = b in place (x := op(A)⁻¹ x) for triangular A (n×n).
pub fn dtrsv(
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    n: usize,
    a: &[f64],
    lda: usize,
    x: &mut [f64],
    incx: usize,
) {
    if n == 0 {
        return;
    }
    let at = |i: usize, j: usize| a[i + j * lda];
    match (uplo, trans) {
        (Uplo::Lower, Trans::No) => {
            for j in 0..n {
                if diag == Diag::NonUnit {
                    x[j * incx] /= at(j, j);
                }
                let t = x[j * incx];
                for i in j + 1..n {
                    x[i * incx] -= t * at(i, j);
                }
            }
        }
        (Uplo::Upper, Trans::No) => {
            for j in (0..n).rev() {
                if diag == Diag::NonUnit {
                    x[j * incx] /= at(j, j);
                }
                let t = x[j * incx];
                for i in 0..j {
                    x[i * incx] -= t * at(i, j);
                }
            }
        }
        (Uplo::Lower, Trans::Yes) => {
            // solve Lᵀ x = b: backward
            for j in (0..n).rev() {
                let mut s = x[j * incx];
                for i in j + 1..n {
                    s -= at(i, j) * x[i * incx];
                }
                x[j * incx] = if diag == Diag::NonUnit { s / at(j, j) } else { s };
            }
        }
        (Uplo::Upper, Trans::Yes) => {
            for j in 0..n {
                let mut s = x[j * incx];
                for i in 0..j {
                    s -= at(i, j) * x[i * incx];
                }
                x[j * incx] = if diag == Diag::NonUnit { s / at(j, j) } else { s };
            }
        }
    }
}

/// x := op(A) x for triangular A (n×n).
pub fn dtrmv(
    uplo: Uplo,
    trans: Trans,
    diag: Diag,
    n: usize,
    a: &[f64],
    lda: usize,
    x: &mut [f64],
    incx: usize,
) {
    let at = |i: usize, j: usize| a[i + j * lda];
    match (uplo, trans) {
        (Uplo::Lower, Trans::No) => {
            for i in (0..n).rev() {
                let mut s = if diag == Diag::NonUnit { at(i, i) * x[i * incx] } else { x[i * incx] };
                for j in 0..i {
                    s += at(i, j) * x[j * incx];
                }
                x[i * incx] = s;
            }
        }
        (Uplo::Upper, Trans::No) => {
            for i in 0..n {
                let mut s = if diag == Diag::NonUnit { at(i, i) * x[i * incx] } else { x[i * incx] };
                for j in i + 1..n {
                    s += at(i, j) * x[j * incx];
                }
                x[i * incx] = s;
            }
        }
        (Uplo::Lower, Trans::Yes) => {
            for i in 0..n {
                let mut s = if diag == Diag::NonUnit { at(i, i) * x[i * incx] } else { x[i * incx] };
                for j in i + 1..n {
                    s += at(j, i) * x[j * incx];
                }
                x[i * incx] = s;
            }
        }
        (Uplo::Upper, Trans::Yes) => {
            for i in (0..n).rev() {
                let mut s = if diag == Diag::NonUnit { at(i, i) * x[i * incx] } else { x[i * incx] };
                for j in 0..i {
                    s += at(j, i) * x[j * incx];
                }
                x[i * incx] = s;
            }
        }
    }
}

/// y := alpha*A*x + beta*y for symmetric A (only `uplo` triangle read).
pub fn dsymv(
    uplo: Uplo,
    n: usize,
    alpha: f64,
    a: &[f64],
    lda: usize,
    x: &[f64],
    incx: usize,
    beta: f64,
    y: &mut [f64],
    incy: usize,
) {
    if beta != 1.0 {
        for i in 0..n {
            y[i * incy] *= beta;
        }
    }
    let at = |i: usize, j: usize| a[i + j * lda];
    for j in 0..n {
        let xj = x[j * incx];
        let mut s = 0.0;
        match uplo {
            Uplo::Lower => {
                y[j * incy] += alpha * at(j, j) * xj;
                for i in j + 1..n {
                    y[i * incy] += alpha * at(i, j) * xj;
                    s += at(i, j) * x[i * incx];
                }
            }
            Uplo::Upper => {
                for i in 0..j {
                    y[i * incy] += alpha * at(i, j) * xj;
                    s += at(i, j) * x[i * incx];
                }
                y[j * incy] += alpha * at(j, j) * xj;
            }
        }
        y[j * incy] += alpha * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn gemv_notrans() {
        // A = [[1,3],[2,4]] col-major, x = [1,1]
        let a = [1.0, 2.0, 3.0, 4.0];
        let x = [1.0, 1.0];
        let mut y = [1.0, 1.0];
        dgemv(Trans::No, 2, 2, 1.0, &a, 2, &x, 1, 2.0, &mut y, 1);
        assert_eq!(y, [6.0, 8.0]); // [4,6] + 2*[1,1]
    }

    #[test]
    fn gemv_trans() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let x = [1.0, 2.0];
        let mut y = [0.0, 0.0];
        dgemv(Trans::Yes, 2, 2, 1.0, &a, 2, &x, 1, 0.0, &mut y, 1);
        assert_eq!(y, [5.0, 11.0]);
    }

    #[test]
    fn ger_rank1() {
        let mut a = [0.0; 4];
        dger(2, 2, 2.0, &[1.0, 2.0], 1, &[3.0, 4.0], 1, &mut a, 2);
        assert_eq!(a, [6.0, 12.0, 8.0, 16.0]);
    }

    #[test]
    fn trsv_inverts_trmv_all_variants() {
        let mut rng = Xoshiro256::seeded(5);
        for &uplo in &[Uplo::Lower, Uplo::Upper] {
            for &trans in &[Trans::No, Trans::Yes] {
                for &diag in &[Diag::NonUnit, Diag::Unit] {
                    let n = 9;
                    let a = Matrix::random_triangular(n, uplo, &mut rng);
                    let x0: Vec<f64> = (0..n).map(|i| (i + 1) as f64).collect();
                    let mut x = x0.clone();
                    dtrmv(uplo, trans, diag, n, &a.data, n, &mut x, 1);
                    dtrsv(uplo, trans, diag, n, &a.data, n, &mut x, 1);
                    for (xi, x0i) in x.iter().zip(&x0) {
                        assert!(
                            (xi - x0i).abs() < 1e-10,
                            "{uplo:?} {trans:?} {diag:?}: {xi} vs {x0i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn symv_matches_full_gemv() {
        let mut rng = Xoshiro256::seeded(6);
        let n = 7;
        let a = Matrix::random_spd(n, &mut rng);
        let x: Vec<f64> = (0..n).map(|i| 0.5 + i as f64).collect();
        let mut y_full = vec![0.0; n];
        dgemv(Trans::No, n, n, 1.5, &a.data, n, &x, 1, 0.0, &mut y_full, 1);
        for &uplo in &[Uplo::Lower, Uplo::Upper] {
            let mut y = vec![0.0; n];
            dsymv(uplo, n, 1.5, &a.data, n, &x, 1, 0.0, &mut y, 1);
            for (a, b) in y.iter().zip(&y_full) {
                assert!((a - b).abs() < 1e-10);
            }
        }
    }
}
