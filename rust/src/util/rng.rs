//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Stands in for the `rand` crate (unavailable offline). Used by the
//! sampler's `dgerand`/`dporand` utility kernels and by the
//! property-test harness; determinism (seeded) keeps experiments and
//! tests reproducible.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so that any u64 (including 0) is a valid seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Xoshiro256 { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in the open interval ]0,1[ — matches the paper's
    /// `xgerand` ("random values uniform in ]0,1[").
    pub fn next_open01(&mut self) -> f64 {
        loop {
            let v = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if v > 0.0 {
                return v;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * ((self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64))
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for test use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.next_open01() < p
    }

    /// Fill a slice with uniform ]0,1[ values.
    pub fn fill_open01(&mut self, buf: &mut [f64]) {
        for v in buf.iter_mut() {
            *v = self.next_open01();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seeded(7);
        let mut b = Xoshiro256::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn open01_bounds() {
        let mut r = Xoshiro256::seeded(42);
        for _ in 0..10_000 {
            let v = r.next_open01();
            assert!(v > 0.0 && v < 1.0);
        }
    }

    #[test]
    fn open01_mean_near_half() {
        let mut r = Xoshiro256::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_open01()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={}", mean);
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.below(5);
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_usize_inclusive() {
        let mut r = Xoshiro256::seeded(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_usize(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
