//! A small, dependency-free JSON implementation.
//!
//! Used for experiment files, report files, and the AOT artifact
//! manifest. Supports the full JSON grammar (RFC 8259) with the usual
//! Rust conveniences: typed accessors, builder-ish constructors, and a
//! pretty printer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization
/// is deterministic (stable diffs for checked-in experiment files).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object member lookup; returns `Json::Null` for missing keys or
    /// non-objects (convenient for optional fields).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element lookup, `Json::Null` out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Pretty-printed serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid unicode escape"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{}", n));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, e) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_json(e, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, e)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(e, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo — ∀b\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ∀b"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"exp":{"calls":[["dgemm","N","N",1000]],"nreps":10,"x":1.25}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse("{\"n\": 3, \"f\": 2.5, \"neg\": -4}").unwrap();
        assert_eq!(v.get("n").as_u64(), Some(3));
        assert_eq!(v.get("f").as_u64(), None);
        assert_eq!(v.get("neg").as_i64(), Some(-4));
        assert_eq!(v.get("neg").as_u64(), None);
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn builders() {
        let mut o = Json::obj();
        o.set("name", "dgemm").set("n", 1000usize).set("ok", true);
        assert_eq!(
            o.to_string_compact(),
            r#"{"n":1000,"name":"dgemm","ok":true}"#
        );
    }

    #[test]
    fn int_formatting_stays_integral() {
        assert_eq!(Json::Num(1e9).to_string_compact(), "1000000000");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
