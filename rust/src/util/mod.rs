//! Self-contained utilities: JSON, RNG, CLI parsing, host/worker
//! identity, property testing.
//!
//! The build environment is offline and the crates.io cache does not
//! provide `serde`, `clap`, `rand` or `proptest`; these small modules
//! implement the subsets ELAPS needs from scratch.

pub mod json;
pub mod rng;
pub mod cli;
pub mod hostid;
pub mod prop;

pub use json::Json;
pub use rng::Xoshiro256;
