//! Minimal command-line argument parsing (the offline registry has no
//! `clap`). Supports subcommands, `--flag`, `--key value`, `--key=value`
//! and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: Vec<String>,
    pub opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `known_flags` lists boolean options (which
    /// consume no value); everything else starting with `--` is treated
    /// as `--key value` or `--key=value`.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_flags: &[&str]) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        args.flags.push(body.to_string());
                    } else {
                        args.opts.insert(body.to_string(), it.next().unwrap());
                    }
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Strict integer option parsing: distinguishes an absent option
    /// (`Ok(None)`) from a present-but-invalid one (`Err`), including
    /// `--name` given without a value. Used for options like `--jobs`
    /// where silently falling back to a default would mask typos.
    pub fn opt_usize_strict(&self, name: &str) -> Result<Option<usize>, String> {
        if let Some(v) = self.opt(name) {
            return v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: '{v}' is not a non-negative integer"));
        }
        if self.flag(name) {
            return Err(format!("--{name} requires a value"));
        }
        Ok(None)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Strict duration option parsing, mirroring
    /// [`Args::opt_usize_strict`]: absent is `Ok(None)`, while a
    /// malformed value or a bare `--name` without one is an `Err` —
    /// used for `--lease-ttl`, `--timeout` and `--max-age`, where a
    /// silently defaulted typo would change lease or gc semantics.
    pub fn opt_duration_strict(
        &self,
        name: &str,
    ) -> Result<Option<std::time::Duration>, String> {
        if let Some(v) = self.opt(name) {
            return parse_duration(v).map(Some).map_err(|e| format!("--{name}: {e}"));
        }
        if self.flag(name) {
            return Err(format!("--{name} requires a duration (e.g. 90s, 5m)"));
        }
        Ok(None)
    }
}

/// Parse a byte-size argument: a non-negative integer with an optional
/// `K`/`M`/`G` suffix (powers of 1024, case-insensitive), e.g. `4096`,
/// `64K`, `2M`, `1G`. Strict: empty, negative, fractional or otherwise
/// malformed input is an error, never a silent default — the callers
/// (`elaps cache gc --max-bytes`) delete data based on this value.
pub fn parse_byte_size(s: &str) -> Result<u64, String> {
    let t = s.trim();
    let bad = || format!("'{s}' is not a byte size (expected N, NK, NM or NG)");
    let (digits, mult): (&str, u64) = match t.chars().last() {
        Some('k') | Some('K') => (&t[..t.len() - 1], 1 << 10),
        Some('m') | Some('M') => (&t[..t.len() - 1], 1 << 20),
        Some('g') | Some('G') => (&t[..t.len() - 1], 1 << 30),
        Some(_) => (t, 1),
        None => return Err(bad()),
    };
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return Err(bad());
    }
    let v: u64 = digits.parse().map_err(|_| bad())?;
    v.checked_mul(mult).ok_or_else(|| format!("'{s}' overflows a 64-bit byte count"))
}

/// Parse a duration argument: a non-negative integer with an optional
/// `s`/`m`/`h`/`d` suffix (case-insensitive), e.g. `90`, `90s`, `30m`,
/// `12h`, `7d`. A bare integer means seconds. Strict, like
/// [`parse_byte_size`]: empty, negative, fractional or otherwise
/// malformed input is an error, never a silent default — the caller
/// (`elaps cache gc --max-age`) deletes data based on this value.
pub fn parse_duration(s: &str) -> Result<std::time::Duration, String> {
    let t = s.trim();
    let bad = || format!("'{s}' is not a duration (expected N, Ns, Nm, Nh or Nd)");
    let (digits, mult): (&str, u64) = match t.chars().last() {
        Some('s') | Some('S') => (&t[..t.len() - 1], 1),
        Some('m') | Some('M') => (&t[..t.len() - 1], 60),
        Some('h') | Some('H') => (&t[..t.len() - 1], 3_600),
        Some('d') | Some('D') => (&t[..t.len() - 1], 86_400),
        Some(_) => (t, 1),
        None => return Err(bad()),
    };
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return Err(bad());
    }
    let v: u64 = digits.parse().map_err(|_| bad())?;
    let secs = v
        .checked_mul(mult)
        .ok_or_else(|| format!("'{s}' overflows a 64-bit second count"))?;
    Ok(std::time::Duration::from_secs(secs))
}

/// Parse a range spec of the form `lo:hi` or `lo:step:hi` (inclusive),
/// e.g. `50:50:2000` → 50, 100, ..., 2000. Mirrors the paper's
/// parameter-range notation "n = 50:50:2000".
pub fn parse_range(spec: &str) -> Option<Vec<usize>> {
    let parts: Vec<&str> = spec.split(':').collect();
    let (lo, step, hi) = match parts.as_slice() {
        [lo, hi] => (lo.parse().ok()?, 1usize, hi.parse().ok()?),
        [lo, step, hi] => (lo.parse().ok()?, step.parse().ok()?, hi.parse().ok()?),
        [single] => {
            let v = single.parse().ok()?;
            return Some(vec![v]);
        }
        _ => return None,
    };
    if step == 0 || hi < lo {
        return None;
    }
    Some((lo..=hi).step_by(step).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixture() {
        let a = Args::parse(
            sv(&["run", "exp.json", "--backend", "xla", "--verbose", "--n=100"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["run", "exp.json"]);
        assert_eq!(a.opt("backend"), Some("xla"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("n", 0), 100);
    }

    #[test]
    fn flag_before_flag() {
        let a = Args::parse(sv(&["--a", "--b", "val"]), &[]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("val"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(sv(&["--x"]), &[]);
        assert!(a.flag("x"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(sv(&[]), &[]);
        assert_eq!(a.opt_or("lib", "rustblocked"), "rustblocked");
        assert_eq!(a.opt_usize("n", 7), 7);
        assert_eq!(a.opt_f64("freq", 2.6e9), 2.6e9);
    }

    #[test]
    fn strict_usize_option() {
        let a = Args::parse(sv(&["--jobs", "4"]), &[]);
        assert_eq!(a.opt_usize_strict("jobs"), Ok(Some(4)));
        assert_eq!(a.opt_usize_strict("cache"), Ok(None));
        let bad = Args::parse(sv(&["--jobs", "four"]), &[]);
        assert!(bad.opt_usize_strict("jobs").is_err());
        // --jobs immediately followed by another option parses as a
        // bare flag: strict parsing reports the missing value
        let missing = Args::parse(sv(&["--jobs", "--cache", "dir"]), &[]);
        assert!(missing.opt_usize_strict("jobs").is_err());
    }

    #[test]
    fn strict_duration_option() {
        let a = Args::parse(sv(&["--timeout", "90s"]), &[]);
        assert_eq!(
            a.opt_duration_strict("timeout"),
            Ok(Some(std::time::Duration::from_secs(90)))
        );
        assert_eq!(a.opt_duration_strict("lease-ttl"), Ok(None));
        let bad = Args::parse(sv(&["--timeout", "soon"]), &[]);
        assert!(bad.opt_duration_strict("timeout").is_err());
        // --timeout followed by another option parses as a bare flag:
        // strict parsing reports the missing value
        let missing = Args::parse(sv(&["--timeout", "--spool", "d"]), &[]);
        assert!(missing.opt_duration_strict("timeout").is_err());
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(parse_byte_size("0"), Ok(0));
        assert_eq!(parse_byte_size("4096"), Ok(4096));
        assert_eq!(parse_byte_size("64K"), Ok(64 * 1024));
        assert_eq!(parse_byte_size("64k"), Ok(64 * 1024));
        assert_eq!(parse_byte_size("2M"), Ok(2 * 1024 * 1024));
        assert_eq!(parse_byte_size("1g"), Ok(1024 * 1024 * 1024));
        assert_eq!(parse_byte_size(" 10K "), Ok(10 * 1024));
        for bad in ["", "   ", "-5", "-5K", "1.5M", "K", "10KB", "ten", "1e6", "+3"] {
            assert!(parse_byte_size(bad).is_err(), "{bad:?} must be rejected");
        }
        // embedded whitespace is rejected (only surrounding trim is
        // forgiven), as are unknown suffixes
        for bad in ["1 0K", "10 K", "1\t0", "1 024", "10Q", "10x"] {
            assert!(parse_byte_size(bad).is_err(), "{bad:?} must be rejected");
        }
        // overflow is an error, not a wrap
        assert!(parse_byte_size("99999999999999999999").is_err());
        assert!(parse_byte_size("18446744073709551615G").is_err());
        // just-at-the-edge values still parse
        assert_eq!(parse_byte_size("18446744073709551615"), Ok(u64::MAX));
    }

    #[test]
    fn durations() {
        use std::time::Duration;
        assert_eq!(parse_duration("0"), Ok(Duration::ZERO));
        assert_eq!(parse_duration("90"), Ok(Duration::from_secs(90)));
        assert_eq!(parse_duration("90s"), Ok(Duration::from_secs(90)));
        assert_eq!(parse_duration("30m"), Ok(Duration::from_secs(1_800)));
        assert_eq!(parse_duration("12H"), Ok(Duration::from_secs(43_200)));
        assert_eq!(parse_duration("7d"), Ok(Duration::from_secs(604_800)));
        assert_eq!(parse_duration(" 5m "), Ok(Duration::from_secs(300)));
        for bad in ["", "   ", "-5", "-5h", "1.5h", "h", "10min", "ten", "1e3", "+3d"] {
            assert!(parse_duration(bad).is_err(), "{bad:?} must be rejected");
        }
        // embedded whitespace, unknown suffixes and compound specs are
        // rejected (only surrounding trim is forgiven)
        for bad in ["1 0s", "10 s", "1\t0", "3h30m", "10w", "5y"] {
            assert!(parse_duration(bad).is_err(), "{bad:?} must be rejected");
        }
        // overflow is an error, not a wrap
        assert!(parse_duration("99999999999999999999").is_err());
        assert!(parse_duration("18446744073709551615d").is_err());
        // just-at-the-edge values still parse
        assert_eq!(parse_duration("18446744073709551615"), Ok(Duration::from_secs(u64::MAX)));
    }

    #[test]
    fn ranges() {
        assert_eq!(parse_range("50:50:200"), Some(vec![50, 100, 150, 200]));
        assert_eq!(parse_range("1:4"), Some(vec![1, 2, 3, 4]));
        assert_eq!(parse_range("7"), Some(vec![7]));
        assert_eq!(parse_range("5:0:10"), None);
        assert_eq!(parse_range("10:5"), None);
        assert_eq!(parse_range("a:b"), None);
    }
}
