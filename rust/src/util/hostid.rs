//! Process and host identity for multi-host provenance: which machine
//! (and which worker process on it) produced a measurement or holds a
//! job lease. The spooler's lease protocol
//! ([`crate::coordinator::lease`]) and the schema-3 result-cache
//! envelope ([`crate::coordinator::io::CacheEnvelope`]) both record
//! these identities.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Best-effort hostname, resolved once per process:
/// `ELAPS_HOST` (explicit override, used by tests and heterogeneous
/// cluster setups) → `HOSTNAME` → `/etc/hostname` → `"localhost"`.
/// Whitespace is trimmed; an empty result falls through to the next
/// source.
pub fn hostname() -> &'static str {
    static HOST: OnceLock<String> = OnceLock::new();
    HOST.get_or_init(|| {
        let from_env = |name: &str| {
            std::env::var(name).ok().map(|v| v.trim().to_string()).filter(|v| !v.is_empty())
        };
        from_env("ELAPS_HOST")
            .or_else(|| from_env("HOSTNAME"))
            .or_else(|| {
                std::fs::read_to_string("/etc/hostname")
                    .ok()
                    .map(|v| v.trim().to_string())
                    .filter(|v| !v.is_empty())
            })
            .unwrap_or_else(|| "localhost".to_string())
    })
}

/// A worker identity unique across hosts, processes *and* within this
/// process: `<host>#<pid>-<seq>`. Each call mints a fresh identity, so
/// every spooler handle (and every worker thread derived from one) can
/// be distinguished in leases and provenance records.
pub fn new_worker_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    format!(
        "{}#{}-{}",
        hostname(),
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hostname_is_stable_and_nonempty() {
        let h = hostname();
        assert!(!h.is_empty());
        assert_eq!(h, hostname(), "resolved once, then cached");
    }

    #[test]
    fn worker_ids_are_unique_and_carry_the_host() {
        let a = new_worker_id();
        let b = new_worker_id();
        assert_ne!(a, b);
        assert!(a.starts_with(hostname()), "{a}");
        assert!(a.contains('#'));
    }
}
