//! A small property-based testing harness (the offline registry has no
//! `proptest`). Runs a property over many randomly generated cases with
//! a deterministic seed, and on failure performs greedy shrinking of the
//! generated integers toward zero.
//!
//! Used for coordinator invariants (unrolling, routing, report
//! reduction) and linalg invariants (e.g. `trsm` inverts `trmm`).

use super::rng::Xoshiro256;

/// Number of cases per property (kept modest: the linalg properties do
/// real factorizations).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` random inputs produced by `gen`. On failure,
/// tries shrinking by re-generating with progressively smaller "size"
/// hints; panics with the failing case's debug representation.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Xoshiro256, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Xoshiro256::seeded(seed);
    for case in 0..cases {
        // size grows with case index so early cases are small/fast
        let size = 1 + case * 4 / cases.max(1) * 8 + case % 8;
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: regenerate at smaller sizes with fresh
            // sub-seeds, keep the smallest failure found.
            let mut smallest: (usize, T, String) = (size, input.clone(), msg.clone());
            for shrink_size in (1..size).rev() {
                let mut srng = Xoshiro256::seeded(seed ^ (shrink_size as u64) << 32);
                let candidate = gen(&mut srng, shrink_size);
                if let Err(m) = prop(&candidate) {
                    smallest = (shrink_size, candidate, m);
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}): {}\nminimal-ish input (size {}): {:#?}",
                smallest.2, smallest.0, smallest.1
            );
        }
    }
}

/// Assert two floats are close in the mixed absolute/relative sense used
/// throughout the linalg tests.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

/// Assert two slices are element-wise close.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        close(x, y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            1,
            50,
            |r, size| r.range_usize(0, size),
            |_| {
                n += 1;
                Ok(())
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(
            2,
            50,
            |r, size| r.range_usize(0, size + 10),
            |&v| if v < 5 { Ok(()) } else { Err(format!("{v} >= 5")) },
        );
    }

    #[test]
    fn close_mixed_tolerance() {
        assert!(close(1e9, 1e9 + 1.0, 1e-8).is_ok());
        assert!(close(1e-12, 0.0, 1e-8).is_ok());
        assert!(close(1.0, 1.1, 1e-8).is_err());
    }

    #[test]
    fn all_close_reports_index() {
        let err = all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-9).unwrap_err();
        assert!(err.contains("index 1"), "{err}");
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }
}
