//! `elaps calibrate` — the calibration sweep and least-squares fit
//! behind fitted machine profiles (ROADMAP item 3).
//!
//! The sweep is itself a campaign of size-staged kernels, built and run
//! through the same [`ExperimentRunner`] plan/replay machinery as the
//! paper figures:
//!
//! * a **compute-bound** stage — a dgemm whose three operands fit in
//!   half of L1, so (after the cold first repetition) its cycles are
//!   pure compute and pin down the effective flops/cycle;
//! * one **streaming** stage per cache level — a dgemv whose matrix
//!   footprint is twice that level's capacity, so every pass misses at
//!   that level (and hits everything below), exposing the level's miss
//!   penalty in isolation.
//!
//! Under a fixed seed the sampler reports the machine model's
//! cache-aware prediction, which is *linear* in (flops, per-level line
//! misses) — the weighted least-squares fit against the simulated
//! [`crate::perfmodel::CacheSim::level_misses`] counters then recovers
//! the model's instance parameters essentially exactly, and
//! `mean_abs_rel_err` measures how far the uncalibrated defaults were
//! from the machine's true constants. On presets whose instance
//! penalties differ from [`DEFAULT_MISS_PENALTY_CYCLES`] (haswell,
//! bluegene, …) the fitted error beats the uncalibrated one by orders
//! of magnitude; on an unseeded (wall-clock) sweep the same fit
//! produces a noisy but honest approximation.

use super::{call, ExperimentRunner, PlanRunner, ReplayRunner};
use crate::coordinator::Experiment;
use crate::engine::{BatchStats, Engine, EngineConfig};
use crate::perfmodel::machine::DEFAULT_MISS_PENALTY_CYCLES;
use crate::perfmodel::{MachineModel, MachineProfile};
use anyhow::{anyhow, bail, Result};

/// Default seed of `elaps calibrate` (overridable with `--seed`). Any
/// fixed value works — the fit only needs the sweep to be modeled, not
/// a particular operand stream.
pub const CALIBRATE_SEED: u64 = 0xCA11B;

/// One calibration observation: the cycles of a single kernel call,
/// its flop count, and the per-level simulated line misses (the
/// `PAPI_L<k>_TCM` counters, innermost first).
#[derive(Debug, Clone)]
pub struct CalRow {
    pub cycles: f64,
    pub flops: f64,
    pub misses: Vec<u64>,
}

/// Cycles the model `(flops_per_cycle, miss_penalty_cycles)` predicts
/// for one observation — the fit's forward function, matching
/// [`MachineModel::modeled_seconds`] (deeper-than-modeled levels reuse
/// the last charge).
fn predict_cycles(fpc: f64, penalties: &[f64], row: &CalRow) -> f64 {
    let mem: f64 = row
        .misses
        .iter()
        .enumerate()
        .map(|(i, &m)| m as f64 * penalties[i.min(penalties.len() - 1)])
        .sum();
    row.flops / fpc + mem
}

/// Mean |predicted − observed| / observed over the sweep.
pub fn mean_abs_rel_err(fpc: f64, penalties: &[f64], rows: &[CalRow]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for r in rows {
        if r.cycles > 0.0 {
            sum += (predict_cycles(fpc, penalties, r) - r.cycles).abs() / r.cycles;
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n
    }
}

/// Solve the dense linear system `a x = b` by Gaussian elimination with
/// partial pivoting (the normal equations are at most 4×4 here). `None`
/// on a (numerically) singular system.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Option<Vec<f64>> {
    let n = b.len();
    let scale = a
        .iter()
        .flat_map(|row| row.iter())
        .fold(0.0f64, |acc, v| acc.max(v.abs()));
    if scale == 0.0 {
        return None;
    }
    for col in 0..n {
        let piv = (col..n).max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))?;
        if a[piv][col].abs() < 1e-12 * scale {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            for c2 in col..n {
                a[row][c2] -= f * a[col][c2];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let s: f64 = (col + 1..n).map(|c2| a[col][c2] * x[c2]).sum();
        x[col] = (b[col] - s) / a[col][col];
    }
    Some(x)
}

/// Weighted least-squares fit of `cycles ≈ flops/fpc + Σ_l misses_l ·
/// p_l` over the sweep rows. Rows are weighted by 1/cycles² so the fit
/// minimizes *relative* error (the sweep spans five orders of magnitude
/// in cycles). Levels the sweep never missed at stay pinned to the base
/// preset's value and are excluded from the solve, which keeps the
/// normal matrix non-singular; a singular fit falls back to the base
/// constants entirely. Returns `(flops_per_cycle, miss_penalty_cycles)`
/// with the penalties clamped non-negative.
pub fn fit(base: &MachineModel, rows: &[CalRow]) -> (f64, Vec<f64>) {
    let nlev = base.caches.len();
    // column 0 = flops; column l+1 = level-l misses, kept only if the
    // sweep observed any miss there
    let mut active = vec![0usize];
    for l in 0..nlev {
        if rows.iter().any(|r| r.misses.get(l).copied().unwrap_or(0) > 0) {
            active.push(l + 1);
        }
    }
    let k = active.len();
    let mut ata = vec![vec![0.0; k]; k];
    let mut atb = vec![0.0; k];
    for r in rows {
        if r.cycles <= 0.0 || r.flops <= 0.0 {
            continue;
        }
        let w = 1.0 / (r.cycles * r.cycles);
        let a: Vec<f64> = active
            .iter()
            .map(|&c| {
                if c == 0 {
                    r.flops
                } else {
                    r.misses.get(c - 1).copied().unwrap_or(0) as f64
                }
            })
            .collect();
        for i in 0..k {
            for j in 0..k {
                ata[i][j] += w * a[i] * a[j];
            }
            atb[i] += w * a[i] * r.cycles;
        }
    }
    let base_penalty = |l: usize| {
        let p = &base.miss_penalty_cycles;
        p[l.min(p.len() - 1)]
    };
    let mut penalties: Vec<f64> = (0..nlev).map(base_penalty).collect();
    let Some(x) = solve(ata, atb) else {
        return (base.flops_per_cycle, penalties);
    };
    let fpc = if x[0] > 1e-12 { 1.0 / x[0] } else { base.flops_per_cycle };
    for (idx, &c) in active.iter().enumerate().skip(1) {
        penalties[c - 1] = x[idx].max(0.0);
    }
    (fpc, penalties)
}

/// The staged calibration campaign for one machine: `cal-compute` plus
/// one `cal-L<k>` streaming stage per cache level, all selecting every
/// level's `TCM` counter and keeping the cold first repetition (its
/// all-level misses add fit rows for free).
fn calibration_experiments(
    spec: &str,
    library: &str,
    base: &MachineModel,
    quick: bool,
) -> Result<Vec<Experiment>> {
    let nreps = if quick { 3 } else { 5 };
    let counters: Vec<String> =
        base.caches.iter().map(|c| format!("PAPI_{}_TCM", c.name)).collect();
    let mut exps = Vec::new();
    let mut stage = |name: String, c: crate::coordinator::Call| {
        exps.push(Experiment {
            name,
            library: library.into(),
            machine: spec.into(),
            nreps,
            discard_first: false,
            counters: counters.clone(),
            calls: vec![c],
            ..Default::default()
        });
    };
    // compute-bound stage: all three dgemm operands in half of L1
    let l1 = base.caches.first().map(|c| c.size_bytes).unwrap_or(32 * 1024);
    let n = (((l1 / 2 / (3 * 8)) as f64).sqrt().floor() as i64).max(8);
    let ns = n.to_string();
    stage(
        "cal-compute".into(),
        call("dgemm", &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns])?,
    );
    // one streaming stage per level: a square dgemv matrix of twice the
    // level's capacity, so each pass misses there and hits below
    for lvl in &base.caches {
        let m = (((2 * lvl.size_bytes / 8) as f64).sqrt().floor() as i64).max(16);
        let ms = m.to_string();
        stage(
            format!("cal-{}", lvl.name),
            call("dgemv", &["N", &ms, &ms, "1.0", "$A", &ms, "$x", "1", "0.0", "$y", "1"])?,
        );
    }
    Ok(exps)
}

/// Run the calibration sweep through `runner` and fit a
/// [`MachineProfile`] for `spec`, which must be a built-in preset name
/// (profiles refine presets; refitting a `profile:PATH` would be
/// circular).
pub fn run_calibration(
    runner: &dyn ExperimentRunner,
    spec: &str,
    library: &str,
    quick: bool,
) -> Result<MachineProfile> {
    let base = MachineModel::by_name(spec).ok_or_else(|| {
        anyhow!(
            "calibrate fits the built-in machine presets (one of {}); got '{spec}'",
            MachineModel::REGISTRY_NAMES.join(", ")
        )
    })?;
    let mut rows = Vec::new();
    for exp in calibration_experiments(spec, library, &base, quick)? {
        let report = runner.run(&exp)?;
        for p in &report.points {
            for r in &p.records {
                if r.cycles > 0.0 && r.flops > 0.0 {
                    rows.push(CalRow {
                        cycles: r.cycles,
                        flops: r.flops,
                        misses: r.counters.clone(),
                    });
                }
            }
        }
    }
    if rows.is_empty() {
        bail!("calibration sweep produced no usable measurement rows");
    }
    let (fpc, penalties) = fit(&base, &rows);
    let uncalibrated: Vec<f64> = (0..base.caches.len())
        .map(|i| DEFAULT_MISS_PENALTY_CYCLES[i.min(DEFAULT_MISS_PENALTY_CYCLES.len() - 1)])
        .collect();
    Ok(MachineProfile {
        name: format!("{spec}+calibrated"),
        base: spec.into(),
        flops_per_cycle: fpc,
        mean_abs_rel_err: mean_abs_rel_err(fpc, &penalties, &rows),
        uncalibrated_mean_abs_rel_err: mean_abs_rel_err(
            base.flops_per_cycle,
            &uncalibrated,
            &rows,
        ),
        miss_penalty_cycles: penalties,
        fit_points: rows.len(),
    })
}

/// The `elaps calibrate` entry point: plan the sweep, measure it as one
/// engine batch under `cfg` (seed it for the exact fit; see module
/// docs), and fit the profile from the replayed reports — the same
/// plan/batch/replay shape as [`super::run_figures_campaign`].
pub fn calibrate(
    spec: &str,
    library: &str,
    quick: bool,
    cfg: EngineConfig,
) -> Result<(MachineProfile, BatchStats)> {
    let plan = PlanRunner::default();
    run_calibration(&plan, spec, library, quick)?;
    let exps = plan.into_experiments();
    let (reports, stats) = Engine::new(cfg).run_batch_stats(&exps)?;
    let replay = ReplayRunner::new(&exps, reports);
    let profile = run_calibration(&replay, spec, library, quick)?;
    Ok((profile, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Report;

    /// A runner that executes every experiment under a fixed seed —
    /// records then carry the machine model's exact predictions.
    struct SeededRunner(u64);

    impl ExperimentRunner for SeededRunner {
        fn run(&self, exp: &Experiment) -> Result<Report> {
            Engine::new(EngineConfig::default().with_seed(self.0)).run(exp)
        }
    }

    #[test]
    fn fit_recovers_haswell_instance_parameters() {
        // haswell's instance penalties [10, 34, 170] differ from the
        // uncalibrated defaults [12, 40, 200]: a seeded sweep is exactly
        // linear in (flops, misses), so the fit must recover them
        let p = run_calibration(&SeededRunner(7), "haswell", "rustblocked", true).unwrap();
        let truth = MachineModel::haswell_laptop();
        assert!(
            (p.flops_per_cycle - truth.flops_per_cycle).abs() < 1e-6,
            "fpc {} vs {}",
            p.flops_per_cycle,
            truth.flops_per_cycle
        );
        assert_eq!(p.miss_penalty_cycles.len(), truth.miss_penalty_cycles.len());
        for (got, want) in p.miss_penalty_cycles.iter().zip(&truth.miss_penalty_cycles) {
            assert!((got - want).abs() < 1e-3, "penalty {got} vs {want}");
        }
        assert!(p.mean_abs_rel_err < 1e-6, "{}", p.mean_abs_rel_err);
        assert!(
            p.uncalibrated_mean_abs_rel_err > 0.01,
            "defaults must visibly mispredict haswell: {}",
            p.uncalibrated_mean_abs_rel_err
        );
        assert!(p.mean_abs_rel_err < p.uncalibrated_mean_abs_rel_err);
        assert_eq!(p.base, "haswell");
        assert!(p.fit_points > 0);
    }

    #[test]
    fn calibrate_campaign_matches_direct_fit() {
        // the plan/batch/replay path must produce the same profile as
        // running the sweep experiment-by-experiment under the seed
        let cfg = EngineConfig::default().with_seed(7);
        let (p, stats) = calibrate("haswell", "rustblocked", true, cfg).unwrap();
        let direct =
            run_calibration(&SeededRunner(7), "haswell", "rustblocked", true).unwrap();
        assert_eq!(p, direct);
        // compute stage + one per cache level
        assert_eq!(stats.experiments, 1 + MachineModel::haswell_laptop().caches.len());
    }

    #[test]
    fn calibrate_rejects_non_preset_specs() {
        let err = run_calibration(&SeededRunner(1), "profile:x.json", "rustblocked", true)
            .unwrap_err()
            .to_string();
        assert!(err.contains("built-in machine presets"), "got: {err}");
        assert!(err.contains("haswell"), "got: {err}");
    }

    #[test]
    fn singular_fits_fall_back_to_base_constants() {
        let base = MachineModel::haswell_laptop();
        // all-zero rows: no flops, no misses — nothing to fit
        let rows = vec![CalRow { cycles: 0.0, flops: 0.0, misses: vec![0, 0, 0] }];
        let (fpc, pen) = fit(&base, &rows);
        assert_eq!(fpc, base.flops_per_cycle);
        assert_eq!(pen, base.miss_penalty_cycles);
    }
}
