//! Scenario pack + multi-library differential studies (ROADMAP item 4;
//! the paper's §4 application scenarios, generalized).
//!
//! Two layers live here:
//!
//! * [`compare_libraries`] — run one operation template across several
//!   backends over a shared parameter grid and assemble a
//!   [`CompareReport`]: per-library series for any [`Metric`], the
//!   winner at every grid point, crossover points where the winner
//!   changes, and a direction-aware library ranking. `elaps compare`
//!   is a thin CLI shell around this; `--predicted` swaps the engine
//!   for a [`PredictiveRunner`], so measured and modeled rankings can
//!   be diffed side by side.
//! * Scenario builders S1–S4 — seeded campaigns on the standard
//!   [`ExperimentRunner`] plumbing (`elaps figures S1 … --seed S`),
//!   each a deterministic end-to-end regression fixture: a blocked
//!   Cholesky block-size sweep, a symbolic operand-size study, a
//!   threads-vs-size efficiency surface, and a cross-library
//!   comparison.

use super::{base, call, ExperimentRunner, FigureBuilder, FigureOutput};
use crate::coordinator::symbolic::Bindings;
use crate::coordinator::{
    DataGen, Experiment, Expr, Figure, Metric, RangeDef, Report, Stat, Vary,
};
use crate::sampler::Sampler;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

// ------------------------------------------------- predictive runner

/// An [`ExperimentRunner`] that never executes a kernel: every point
/// runs on a fresh predictive sampler (`Sampler::predictive`), exactly
/// the engine's cold seeded semantics, so its reports are bit-identical
/// to what a seeded `elaps run` would measure. This is `elaps rank`'s
/// per-point loop behind the runner abstraction — `elaps compare
/// --predicted` and model-vs-measurement diffs run on it.
pub struct PredictiveRunner {
    pub seed: u64,
    /// Overrides each experiment's machine spec when set.
    pub machine_spec: Option<String>,
}

impl PredictiveRunner {
    pub fn new(seed: u64) -> PredictiveRunner {
        PredictiveRunner { seed, machine_spec: None }
    }
}

impl ExperimentRunner for PredictiveRunner {
    fn run(&self, exp: &Experiment) -> Result<Report> {
        let spec = self.machine_spec.as_deref().unwrap_or(&exp.machine);
        let machine = crate::perfmodel::resolve_machine(spec)?;
        let library = crate::libraries::by_name(&exp.library)
            .ok_or_else(|| anyhow!("unknown library '{}'", exp.library))?;
        let mut points = Vec::new();
        for pt in exp.unroll()? {
            let mut sampler =
                Sampler::new(Arc::clone(&library), machine.clone()).predictive(self.seed);
            points.push(crate::engine::execute_point_on(&mut sampler, exp, &pt)?);
        }
        Report::assemble(exp.clone(), machine, points)
    }

    // the default warm/cold legs spin up a real engine; a predictive
    // runner must stay execution-free, and modeled warm == cold anyway
    fn run_warm(&self, exp: &Experiment) -> Result<Report> {
        self.run(exp)
    }

    fn run_cold(&self, exp: &Experiment) -> Result<Report> {
        self.run(exp)
    }
}

// ------------------------------------------------ differential report

/// One backend's series over the shared grid.
pub struct LibrarySeries {
    pub library: String,
    /// (range value, metric value) per grid point.
    pub series: Vec<(i64, f64)>,
}

/// One entry of the differential ranking.
pub struct RankEntry {
    pub library: String,
    /// Mean of the metric over the grid (the ranking key, compared in
    /// the metric's [`Metric::lower_is_better`] direction).
    pub score: f64,
    /// Number of grid points this library wins outright.
    pub wins: usize,
}

/// The ranked differential report of one operation across backends.
pub struct CompareReport {
    pub experiment: String,
    pub machine: String,
    pub metric: Metric,
    pub stat: Stat,
    /// "measured" or "predicted".
    pub mode: String,
    pub libraries: Vec<LibrarySeries>,
    /// Per grid point: (range value, winning library, its value).
    pub winners: Vec<(i64, String, f64)>,
    /// Winner changes along the grid: (at range value, from, to).
    pub crossovers: Vec<(i64, String, String)>,
    /// Libraries best-first by direction-aware mean score; ties break
    /// by library name, so the ordering is deterministic.
    pub ranking: Vec<RankEntry>,
}

impl CompareReport {
    /// The stable `--json` contract of `elaps compare`.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("experiment", self.experiment.as_str());
        j.set("machine", self.machine.as_str());
        j.set("metric", self.metric.name());
        j.set("stat", self.stat.name());
        j.set("mode", self.mode.as_str());
        j.set("lower_is_better", self.metric.lower_is_better());
        let series: Vec<Json> = self
            .libraries
            .iter()
            .map(|ls| {
                let mut o = Json::obj();
                o.set("library", ls.library.as_str());
                let pts: Vec<Json> = ls
                    .series
                    .iter()
                    .map(|&(x, v)| {
                        let mut p = Json::obj();
                        p.set("range_value", x);
                        p.set("value", v);
                        p
                    })
                    .collect();
                o.set("points", pts);
                o
            })
            .collect();
        j.set("series", series);
        let winners: Vec<Json> = self
            .winners
            .iter()
            .map(|(x, lib, v)| {
                let mut o = Json::obj();
                o.set("range_value", *x);
                o.set("library", lib.as_str());
                o.set("value", *v);
                o
            })
            .collect();
        j.set("winners", winners);
        let crossovers: Vec<Json> = self
            .crossovers
            .iter()
            .map(|(x, from, to)| {
                let mut o = Json::obj();
                o.set("at", *x);
                o.set("from", from.as_str());
                o.set("to", to.as_str());
                o
            })
            .collect();
        j.set("crossovers", crossovers);
        let ranking: Vec<Json> = self
            .ranking
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let mut o = Json::obj();
                o.set("rank", i + 1);
                o.set("library", r.library.as_str());
                o.set("score", r.score);
                o.set("wins", r.wins);
                o
            })
            .collect();
        j.set("ranking", ranking);
        j
    }

    /// Multi-series figure with dashed markers at every crossover.
    pub fn to_figure(&self) -> Figure {
        let mut fig = Figure::new(
            &format!("{} — {} across libraries ({})", self.experiment, self.metric.name(), self.mode),
            "range value",
            &self.metric.name(),
        );
        for ls in &self.libraries {
            fig.add_iseries(&ls.library, &ls.series);
        }
        for (x, from, to) in &self.crossovers {
            fig.add_vline(*x as f64, &format!("{from}→{to}"));
        }
        fig
    }

    /// CSV rows: the per-library grid, the winner column, then the
    /// ranking block.
    pub fn csv_rows(&self) -> Vec<String> {
        let mut rows = vec![format!(
            "range_value,{},winner",
            self.libraries.iter().map(|l| l.library.as_str()).collect::<Vec<_>>().join(",")
        )];
        for (i, (x, winner, _)) in self.winners.iter().enumerate() {
            let vals: Vec<String> =
                self.libraries.iter().map(|l| format!("{:.6}", l.series[i].1)).collect();
            rows.push(format!("{x},{},{winner}", vals.join(",")));
        }
        rows.push(String::new());
        rows.push("rank,library,score,wins".into());
        for (i, r) in self.ranking.iter().enumerate() {
            rows.push(format!("{},{},{:.6},{}", i + 1, r.library, r.score, r.wins));
        }
        rows
    }
}

/// Run `template` once per backend in `libs` (same grid, same calls —
/// only the library differs) through one `run_batch`, and assemble the
/// ranked differential report for `metric`/`stat`.
pub fn compare_libraries(
    runner: &dyn ExperimentRunner,
    template: &Experiment,
    libs: &[String],
    metric: Metric,
    stat: Stat,
    mode: &str,
) -> Result<CompareReport> {
    if libs.is_empty() {
        bail!("no libraries to compare");
    }
    let mut exps = Vec::with_capacity(libs.len());
    for lib in libs {
        let mut exp = template.clone();
        exp.library = lib.clone();
        exp.name = format!("{}-{lib}", template.name);
        exps.push(exp);
    }
    let reports = runner.run_batch(&exps)?;
    let machine =
        reports.first().map(|r| r.machine.name.clone()).unwrap_or_default();
    let libraries: Vec<LibrarySeries> = libs
        .iter()
        .zip(&reports)
        .map(|(lib, report)| LibrarySeries {
            library: lib.clone(),
            series: report.series(metric, stat),
        })
        .collect();
    // the grid must be shared — differential columns are meaningless
    // otherwise
    let xs: Vec<i64> = libraries[0].series.iter().map(|&(x, _)| x).collect();
    for ls in &libraries[1..] {
        let other: Vec<i64> = ls.series.iter().map(|&(x, _)| x).collect();
        if other != xs {
            bail!(
                "library '{}' measured grid {:?}, expected {:?}",
                ls.library,
                other,
                xs
            );
        }
    }
    let lower = metric.lower_is_better();
    let better = |v: f64, than: f64| if lower { v < than } else { v > than };
    let mut winners = Vec::with_capacity(xs.len());
    for (i, &x) in xs.iter().enumerate() {
        // ties keep the earliest library in `libs` order — deterministic
        let mut best = (&libraries[0].library, libraries[0].series[i].1);
        for ls in &libraries[1..] {
            if better(ls.series[i].1, best.1) {
                best = (&ls.library, ls.series[i].1);
            }
        }
        winners.push((x, best.0.clone(), best.1));
    }
    let crossovers: Vec<(i64, String, String)> = winners
        .windows(2)
        .filter(|w| w[0].1 != w[1].1)
        .map(|w| (w[1].0, w[0].1.clone(), w[1].1.clone()))
        .collect();
    let mut ranking: Vec<RankEntry> = libraries
        .iter()
        .map(|ls| RankEntry {
            library: ls.library.clone(),
            score: ls.series.iter().map(|&(_, v)| v).sum::<f64>() / ls.series.len() as f64,
            wins: winners.iter().filter(|(_, w, _)| *w == ls.library).count(),
        })
        .collect();
    ranking.sort_by(|a, b| {
        let ord = if lower {
            a.score.total_cmp(&b.score)
        } else {
            b.score.total_cmp(&a.score)
        };
        ord.then_with(|| a.library.cmp(&b.library))
    });
    Ok(CompareReport {
        experiment: template.name.clone(),
        machine,
        metric,
        stat,
        mode: mode.to_string(),
        libraries,
        winners,
        crossovers,
        ranking,
    })
}

/// Operations `elaps compare` knows how to template over a square-ish
/// `n` grid.
pub const COMPARE_OPS: &[&str] = &["dgemm", "dtrsyl", "dpotrf", "dgetrf"];

/// Build the shared comparison template for one operation: a range
/// sweep `n ∈ values` with per-operation calls and operand generators.
pub fn op_experiment(op: &str, values: Vec<i64>, nreps: usize) -> Result<Experiment> {
    if values.is_empty() {
        bail!("empty parameter grid");
    }
    let mut exp = base(&format!("compare-{op}"), "rustblocked");
    exp.nreps = nreps;
    exp.range = Some(RangeDef::new("n", values));
    match op {
        "dgemm" => {
            exp.calls = vec![call(
                "dgemm",
                &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
            )?];
        }
        "dtrsyl" => {
            exp.calls = vec![call(
                "dtrsyl",
                &["N", "N", "1", "n", "n", "$A", "n", "$B", "n", "$C", "n"],
            )?];
            exp.datagen.insert("A".into(), DataGen::Tri(Expr::sym("n"), 'U'));
            exp.datagen.insert("B".into(), DataGen::Tri(Expr::sym("n"), 'U'));
        }
        "dpotrf" => {
            exp.calls = vec![call("dpotrf", &["L", "n", "$A", "n"])?];
            exp.datagen.insert("A".into(), DataGen::Spd(Expr::sym("n")));
            // dpotrf overwrites A with its factor, which is not SPD —
            // a fresh matrix per repetition keeps every rep valid
            exp.vary.insert("A".into(), Vary { with_rep: true, ..Default::default() });
        }
        "dgetrf" => {
            exp.calls = vec![call("dgetrf", &["n", "n", "$A", "n"])?];
            exp.vary.insert("A".into(), Vary { with_rep: true, ..Default::default() });
        }
        other => bail!(
            "unsupported compare operation '{other}' (supported: {})",
            COMPARE_OPS.join(", ")
        ),
    }
    Ok(exp)
}

// ----------------------------------------------------- scenario pack

/// S1 — blocked-algorithm block-size sweep: right-looking blocked
/// Cholesky, one sum-range step per diagonal block (dpotrf on the
/// nb×nb diagonal block, dtrsm for the panel, dsyrk for the trailing
/// update — sizes are symbolic in the block index `i`).
pub fn s1_blocked_cholesky(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n: i64 = if quick { 256 } else { 1024 };
    let nbs: Vec<i64> = if quick {
        vec![8, 16, 32, 64]
    } else {
        vec![8, 16, 32, 64, 96, 128, 192, 256]
    };
    let mut pts = Vec::new();
    let mut rows = vec!["nb,gflops".to_string()];
    for &nb in &nbs {
        let nbs_ = nb.to_string();
        let mut exp = base(&format!("s1-chol-nb{nb}"), "rustblocked");
        exp.nreps = 3;
        let steps: Vec<i64> = (0..n).step_by(nb as usize).collect();
        exp.sumrange = Some(RangeDef::new("i", steps));
        let rem = format!("max({n} - i - {nb}, 0)");
        let remld = format!("max({n} - i - {nb}, 1)");
        exp.calls = vec![
            call("dpotrf", &["L", &nbs_, "$A11", &nbs_])?,
            call(
                "dtrsm",
                &["R", "L", "T", "N", &rem, &nbs_, "1.0", "$A11", &nbs_, "$A21", &remld],
            )?,
            call(
                "dsyrk",
                &["L", "N", &rem, &nbs_, "-1.0", "$A21", &remld, "1.0", "$A22", &remld],
            )?,
        ];
        exp.datagen.insert("A11".into(), DataGen::Spd(Expr::Const(nb)));
        // re-factoring a Cholesky factor is invalid — fresh SPD block
        // per sum-range step and repetition
        exp.vary.insert(
            "A11".into(),
            Vary { with_sumrange: true, with_rep: true, pad_elems: 0 },
        );
        let report = runner.run(&exp)?;
        // rate against the true Cholesky flop count n³/3
        let secs = report.series(Metric::TimeS, Stat::Median)[0].1;
        let gflops =
            if secs > 0.0 { (n as f64).powi(3) / 3.0 / secs / 1e9 } else { 0.0 };
        rows.push(format!("{nb},{gflops:.4}"));
        pts.push((nb, gflops));
    }
    let mut fig = Figure::new(
        &format!("S1 — blocked Cholesky block-size sweep, n={n}"),
        "block size nb",
        "Gflops/s",
    );
    fig.add_iseries("rustblocked", &pts);
    let best = pts.iter().cloned().fold((0i64, 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
    Ok(FigureOutput {
        id: "S1",
        title: "S1 — block-size tuning of blocked Cholesky".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "best nb = {} at {:.2} Gflops/s. Interior optimum expected: tiny nb is \
             panel-bound, huge nb is unblocked-dpotrf-bound. Seeded runs replay \
             byte-identically (regression fixture).",
            best.0, best.1
        ),
    })
}

/// S2 — symbolic operand-size study: one dgemm whose column and depth
/// dimensions are symbolic expressions of the swept size
/// (`ceildiv(n, 4)` and `min(n, 64)`), exercising the
/// `coordinator/symbolic.rs` grammar end to end through script
/// generation; the CSV re-evaluates the same expressions per point.
pub fn s2_symbolic_sizes(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (lo, step, hi): (i64, i64, i64) = if quick { (32, 32, 160) } else { (64, 64, 640) };
    let cols = Expr::parse("ceildiv(n, 4)").map_err(|e| anyhow!(e))?;
    let depth = Expr::parse("min(n, 64)").map_err(|e| anyhow!(e))?;
    let mut exp = base("s2-symbolic", "rustblocked");
    exp.nreps = 3;
    exp.range = Some(RangeDef::span("n", lo, step, hi));
    exp.calls = vec![call(
        "dgemm",
        &[
            "N",
            "N",
            "n",
            "ceildiv(n, 4)",
            "min(n, 64)",
            "1.0",
            "$A",
            "n",
            "$B",
            "min(n, 64)",
            "0.0",
            "$C",
            "n",
        ],
    )?];
    let report = runner.run(&exp)?;
    let series = report.series(Metric::Gflops, Stat::Median);
    let mut rows = vec!["n,cols,depth,gflops".to_string()];
    let mut pts = Vec::new();
    for &(x, g) in &series {
        let mut b = Bindings::new();
        b.insert("n".into(), x);
        let c = cols.eval(&b).map_err(|e| anyhow!(e))?;
        let d = depth.eval(&b).map_err(|e| anyhow!(e))?;
        rows.push(format!("{x},{c},{d},{g:.4}"));
        pts.push((x, g));
    }
    let mut fig = Figure::new(
        "S2 — symbolic operand sizes: C(n×⌈n/4⌉) += A·B, k=min(n,64)",
        "n",
        "Gflops/s",
    );
    fig.add_iseries("rustblocked", &pts);
    Ok(FigureOutput {
        id: "S2",
        title: "S2 — symbolic operand-size study".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "n = {lo}:{step}:{hi}; cols = ceildiv(n, 4), depth = min(n, 64) — the \
             rate should flatten once the depth cap engages at n ≥ 64. Sizes in the \
             CSV are re-evaluated from the same symbolic expressions the sampler \
             script used."
        ),
    })
}

/// S3 — threads-vs-size efficiency surface: the same dgemm sweep at
/// 1/2/4/8 library threads (thread-scaling model on a 1-core host,
/// DESIGN.md §Subst 4), reported as efficiency so the surface shows
/// where parallelism stops paying.
pub fn s3_thread_surface(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (lo, step, hi): (i64, i64, i64) = if quick { (64, 64, 256) } else { (128, 128, 768) };
    let threads: &[i64] = &[1, 2, 4, 8];
    let mut exps = Vec::with_capacity(threads.len());
    for &t in threads {
        let mut exp = base(&format!("s3-threads{t}"), "rustblocked");
        exp.machine = "sandybridge".into();
        exp.nreps = 3;
        exp.nthreads = Expr::Const(t);
        exp.range = Some(RangeDef::span("n", lo, step, hi));
        exp.calls = vec![call(
            "dgemm",
            &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
        )?];
        exps.push(exp);
    }
    let reports = runner.run_batch(&exps)?;
    let mut fig = Figure::new(
        "S3 — efficiency surface: dgemm over size × threads (simulated threads)",
        "n",
        "efficiency [%]",
    );
    let mut per_thread: Vec<Vec<(i64, f64)>> = Vec::new();
    for (&t, report) in threads.iter().zip(&reports) {
        let s = report.series(Metric::Efficiency, Stat::Median);
        fig.add_iseries(&format!("{t} thread(s)"), &s);
        per_thread.push(s);
    }
    let mut rows = vec![format!(
        "n,{}",
        threads.iter().map(|t| format!("eff_t{t}")).collect::<Vec<_>>().join(",")
    )];
    for (i, &(x, _)) in per_thread[0].iter().enumerate() {
        let vals: Vec<String> =
            per_thread.iter().map(|s| format!("{:.3}", s[i].1)).collect();
        rows.push(format!("{x},{}", vals.join(",")));
    }
    Ok(FigureOutput {
        id: "S3",
        title: "S3 — threads-vs-size efficiency surface".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "n = {lo}:{step}:{hi} at 1/2/4/8 library threads on the sandybridge \
             model. SIMULATED THREADS: efficiency is measured against the thread \
             count's peak, so small sizes at high thread counts sit lowest — the \
             surface's diagonal is where parallelism starts paying."
        ),
    })
}

/// S4 — cross-library comparison: the full differential report
/// ([`compare_libraries`]) of one Cholesky factorization across every
/// built-in backend, through the standard runner plumbing.
pub fn s4_cross_library(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let values: Vec<i64> = if quick {
        vec![32, 64, 96, 128]
    } else {
        vec![64, 128, 192, 256, 384, 512]
    };
    let template = op_experiment("dpotrf", values, 3)?;
    let libs: Vec<String> =
        crate::libraries::RUST_LIBRARIES.iter().map(|s| s.to_string()).collect();
    let cmp = compare_libraries(runner, &template, &libs, Metric::Gflops, Stat::Median, "measured")?;
    let mut rows = cmp.csv_rows();
    rows.push(String::new());
    rows.push("crossover_at,from,to".into());
    for (x, from, to) in &cmp.crossovers {
        rows.push(format!("{x},{from},{to}"));
    }
    let best = cmp.ranking.first().map(|r| r.library.clone()).unwrap_or_default();
    Ok(FigureOutput {
        id: "S4",
        title: "S4 — dpotrf across libraries (differential report)".into(),
        figure: Some(cmp.to_figure()),
        rows,
        notes: format!(
            "winner-per-point, crossovers and direction-aware ranking over \
             {} backends; overall best: {best}. The same assembly backs \
             `elaps compare`, which adds --predicted for model-vs-measurement \
             diffs.",
            cmp.libraries.len()
        ),
    })
}

/// The scenario-pack registry (ids S1…S4), merged into
/// [`super::builder_registry`] so `elaps figures S1 …` runs them like
/// any paper figure.
pub fn scenario_builders() -> Vec<(&'static str, FigureBuilder)> {
    vec![
        ("S1", s1_blocked_cholesky),
        ("S2", s2_symbolic_sizes),
        ("S3", s3_thread_surface),
        ("S4", s4_cross_library),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::figures::LocalRunner;

    fn seeded_predictive() -> PredictiveRunner {
        PredictiveRunner::new(7)
    }

    #[test]
    fn op_experiment_rejects_unknown_and_empty() {
        assert!(op_experiment("dfoo", vec![64], 2).is_err());
        assert!(op_experiment("dgemm", vec![], 2).is_err());
    }

    #[test]
    fn compare_report_shape_and_determinism() {
        let template = op_experiment("dgemm", vec![16, 32, 48], 2).unwrap();
        let libs: Vec<String> =
            crate::libraries::RUST_LIBRARIES.iter().map(|s| s.to_string()).collect();
        let runner = seeded_predictive();
        let a = compare_libraries(&runner, &template, &libs, Metric::Gflops, Stat::Median, "predicted")
            .unwrap();
        assert_eq!(a.libraries.len(), libs.len());
        assert_eq!(a.winners.len(), 3);
        assert_eq!(a.ranking.len(), libs.len());
        // ranking is direction-aware: best-first by Gflops mean
        for w in a.ranking.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        // wins sum to the number of grid points
        assert_eq!(a.ranking.iter().map(|r| r.wins).sum::<usize>(), 3);
        // same seed → byte-identical JSON
        let b = compare_libraries(&runner, &template, &libs, Metric::Gflops, Stat::Median, "predicted")
            .unwrap();
        assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    }

    #[test]
    fn compare_time_metric_ranks_lowest_first() {
        let template = op_experiment("dgemm", vec![16, 32], 2).unwrap();
        let libs: Vec<String> =
            crate::libraries::RUST_LIBRARIES.iter().map(|s| s.to_string()).collect();
        let cmp = compare_libraries(
            &seeded_predictive(),
            &template,
            &libs,
            Metric::TimeS,
            Stat::Median,
            "predicted",
        )
        .unwrap();
        for w in cmp.ranking.windows(2) {
            assert!(w[0].score <= w[1].score, "time ranking must be ascending");
        }
        // winner at each point is the per-point minimum
        for (i, (_, winner, v)) in cmp.winners.iter().enumerate() {
            for ls in &cmp.libraries {
                assert!(
                    ls.series[i].1 >= *v || ls.library == *winner,
                    "winner must hold the minimum"
                );
            }
        }
    }

    #[test]
    fn compare_rejects_empty_library_list() {
        let template = op_experiment("dgemm", vec![16], 1).unwrap();
        let r = compare_libraries(
            &seeded_predictive(),
            &template,
            &[],
            Metric::Gflops,
            Stat::Median,
            "predicted",
        );
        assert!(r.is_err());
    }

    #[test]
    fn predicted_matches_measured_under_seed() {
        // the predictive runner and a seeded engine run must agree
        // bit-for-bit (the PR-9 invariant, here through compare)
        let template = op_experiment("dgemm", vec![16, 32], 2).unwrap();
        let libs = vec!["rustref".to_string(), "rustblocked".to_string()];
        let predicted = compare_libraries(
            &seeded_predictive(),
            &template,
            &libs,
            Metric::TimeS,
            Stat::Median,
            "predicted",
        )
        .unwrap();
        let cfg = crate::engine::EngineConfig::default().with_seed(7);
        let engine = crate::engine::Engine::new(cfg);
        let mut exps = Vec::new();
        for lib in &libs {
            let mut e = template.clone();
            e.library = lib.clone();
            e.name = format!("{}-{lib}", template.name);
            exps.push(e);
        }
        let reports = engine.run_batch(&exps).unwrap();
        for (ls, report) in predicted.libraries.iter().zip(&reports) {
            assert_eq!(ls.series, report.series(Metric::TimeS, Stat::Median), "{}", ls.library);
        }
    }

    #[test]
    fn scenarios_run_quick_on_predictive_runner() {
        let runner = seeded_predictive();
        for (id, builder) in scenario_builders() {
            let out = builder(&runner, true).unwrap_or_else(|e| panic!("{id}: {e:#}"));
            assert_eq!(out.id, id);
            assert!(out.rows.len() > 1, "{id} must emit data rows");
        }
    }

    #[test]
    fn s4_runs_through_local_runner() {
        let out = s4_cross_library(&LocalRunner, true).unwrap();
        assert_eq!(out.id, "S4");
        assert!(out.rows.iter().any(|r| r.starts_with("rank,library")));
    }
}
