//! Experiment builders + runners for every table and figure of the
//! paper's evaluation (see DESIGN.md per-experiment index). Each
//! builder constructs the paper's experiment (at the scaled sizes of
//! §Substitutions 7 when `quick` is off, smaller still when on), runs
//! it, and returns a [`FigureOutput`] with the plot and CSV rows the
//! benches and the `elaps figures` command write out.
//!
//! Execution routes through [`crate::engine`]: `elaps figures --jobs N
//! --cache DIR` (or `ELAPS_JOBS` / `ELAPS_CACHE` for the bench
//! binaries) fans the builders' experiment points out over a worker
//! pool and re-uses cached measurements across overlapping campaigns.
//!
//! Builders are written against the [`ExperimentRunner`] abstraction,
//! which lets [`run_figures_campaign`] run a whole campaign in two
//! passes: a *plan* pass ([`PlanRunner`]) walks every requested builder
//! without executing anything to collect its experiments, everything is
//! then measured through **one** [`crate::engine::Engine::run_batch`]
//! (campaign-level sharding, one [`crate::engine::BatchStats`]), and a
//! *replay* pass ([`ReplayRunner`]) hands each builder its measured
//! reports to assemble the figure outputs.

pub mod calibrate;
pub mod scenarios;

use crate::coordinator::{
    run_local, Call, CallArg, DataGen, Experiment, Expr, Figure, Metric, PointResult,
    RangeDef, Report, Stat, Vary,
};
use crate::engine::BatchStats;
use crate::kernels::ArgRole;
use crate::sampler::Record;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};

/// The output of one reproduced table/figure.
pub struct FigureOutput {
    /// Paper id: "T1", "F4", …
    pub id: &'static str,
    pub title: String,
    pub figure: Option<Figure>,
    /// CSV rows (first row = header).
    pub rows: Vec<String>,
    /// Reproduction notes (scaling, simulated-threads marker, …).
    pub notes: String,
}

impl FigureOutput {
    /// Write `<dir>/<id>.csv`, `<id>.svg`, `<id>.txt`.
    pub fn write_to(&self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), self.rows.join("\n") + "\n")?;
        if let Some(fig) = &self.figure {
            std::fs::write(dir.join(format!("{}.svg", self.id)), fig.to_svg(720, 440))?;
            std::fs::write(
                dir.join(format!("{}.txt", self.id)),
                format!("{}\n{}\n{}", self.title, fig.to_ascii(70, 20), self.notes),
            )?;
        } else {
            std::fs::write(
                dir.join(format!("{}.txt", self.id)),
                format!("{}\n{}\n{}", self.title, self.rows.join("\n"), self.notes),
            )?;
        }
        Ok(())
    }
}

// ----------------------------------------------------------- runners

/// How a figure builder executes its experiments. Builders construct
/// their experiments deterministically and never derive one experiment
/// from another's *measurements*, so a campaign can run every builder
/// twice — once against [`PlanRunner`] to learn the experiment list,
/// once against [`ReplayRunner`] to assemble outputs from the batch's
/// reports.
pub trait ExperimentRunner {
    fn run(&self, exp: &Experiment) -> Result<Report>;

    /// Run several experiments; the default runs them one by one.
    fn run_batch(&self, exps: &[Experiment]) -> Result<Vec<Report>> {
        exps.iter().map(|e| self.run(e)).collect()
    }

    /// Run one experiment in **warm** execution mode (per-worker
    /// sampler reuse, [`crate::engine::EngineConfig::warm`]). Warmth is
    /// an engine-level axis, not an experiment-level one, so warm legs
    /// cannot ride the campaign's shared batch: the default runs a
    /// dedicated serial warm engine on top of the process-default
    /// config. [`PlanRunner`] overrides this with a placeholder so the
    /// plan pass stays measurement-free.
    fn run_warm(&self, exp: &Experiment) -> Result<Report> {
        let cfg = crate::engine::default_config().with_warm(true).with_jobs(1);
        crate::engine::Engine::new(cfg).run(exp)
    }

    /// Run one experiment in explicitly **cold** execution mode (a
    /// fresh sampler per point) regardless of the process-default
    /// engine config — the counterpart of [`ExperimentRunner::run_warm`]
    /// for builders that *compare* the two modes and must not let an
    /// `ELAPS_WARM=1` / `--warm` default silently warm up their cold
    /// leg.
    fn run_cold(&self, exp: &Experiment) -> Result<Report> {
        let cfg = crate::engine::default_config().with_warm(false);
        crate::engine::Engine::new(cfg).run(exp)
    }
}

/// Immediate execution through the process-default engine
/// configuration — the standalone (`run_figure`, bench binary) path.
pub struct LocalRunner;

impl ExperimentRunner for LocalRunner {
    fn run(&self, exp: &Experiment) -> Result<Report> {
        run_local(exp)
    }

    fn run_batch(&self, exps: &[Experiment]) -> Result<Vec<Report>> {
        crate::engine::Engine::with_defaults().run_batch(exps)
    }
}

/// The campaign's plan pass: records every experiment a builder
/// submits and returns a placeholder report of the correct *shape*
/// (points, record counts, kernel labels) filled with nominal values,
/// so builder code runs to completion without measuring anything. The
/// outputs computed during this pass are discarded.
#[derive(Default)]
pub struct PlanRunner {
    collected: RefCell<Vec<Experiment>>,
}

impl PlanRunner {
    pub fn into_experiments(self) -> Vec<Experiment> {
        self.collected.into_inner()
    }
}

impl ExperimentRunner for PlanRunner {
    fn run(&self, exp: &Experiment) -> Result<Report> {
        self.collected.borrow_mut().push(exp.clone());
        placeholder_report(exp)
    }

    /// Warm and forced-cold legs are not batchable (engine-level axis),
    /// so the plan pass neither collects nor measures them — the replay
    /// pass runs them live through the default implementations.
    fn run_warm(&self, exp: &Experiment) -> Result<Report> {
        placeholder_report(exp)
    }

    fn run_cold(&self, exp: &Experiment) -> Result<Report> {
        placeholder_report(exp)
    }
}

/// The campaign's replay pass: serves the reports measured by the
/// campaign batch, matched by the experiment's canonical JSON. A
/// builder that (unexpectedly) asks for an experiment the plan pass
/// did not record falls back to local execution.
pub struct ReplayRunner {
    by_exp: RefCell<HashMap<String, VecDeque<Report>>>,
}

impl ReplayRunner {
    /// Pair the planned experiments with their batch reports (same
    /// order, as returned by `run_batch`).
    pub fn new(exps: &[Experiment], reports: Vec<Report>) -> ReplayRunner {
        let mut by_exp: HashMap<String, VecDeque<Report>> = HashMap::new();
        for (exp, report) in exps.iter().zip(reports) {
            by_exp.entry(exp_key(exp)).or_default().push_back(report);
        }
        ReplayRunner { by_exp: RefCell::new(by_exp) }
    }
}

impl ExperimentRunner for ReplayRunner {
    fn run(&self, exp: &Experiment) -> Result<Report> {
        let popped = self.by_exp.borrow_mut().get_mut(&exp_key(exp)).and_then(|q| q.pop_front());
        match popped {
            Some(report) => Ok(report),
            None => run_local(exp),
        }
    }
}

/// Canonical identity of an experiment for plan/replay matching.
fn exp_key(exp: &Experiment) -> String {
    crate::coordinator::io::experiment_to_json(exp).to_string_compact()
}

/// A structurally correct report with nominal (1 ms / 1 flop) records —
/// the plan pass stand-in. Kernel labels follow the call list so
/// per-call breakdowns keep their shape.
fn placeholder_report(exp: &Experiment) -> Result<Report> {
    let machine = crate::perfmodel::resolve_machine(&exp.machine)?;
    let ncounters = exp.counters.len();
    let points: Vec<PointResult> = exp
        .unroll()?
        .into_iter()
        .map(|pt| {
            let records = (0..pt.expected_records(exp.nreps))
                .map(|i| {
                    let kernel = exp
                        .calls
                        .get(i % pt.calls_per_iter.max(1))
                        .map(|c| c.kernel.clone())
                        .unwrap_or_else(|| "planned".into());
                    Record {
                        kernel,
                        seconds: 1e-3,
                        cycles: machine.cycles(1e-3),
                        flops: 1.0,
                        counters: vec![0; ncounters],
                        omp_group: None,
                    }
                })
                .collect();
            PointResult {
                range_value: pt.range_value,
                nthreads: pt.nthreads,
                sum_iters: pt.sum_iters,
                calls_per_iter: pt.calls_per_iter,
                records,
            }
        })
        .collect();
    Report::assemble(exp.clone(), machine, points)
}

/// Build a [`Call`] from compact tokens: `$name` = operand, otherwise
/// parsed per the signature role (flag char / expression / scalar).
pub fn call(kernel: &str, toks: &[&str]) -> Result<Call> {
    let sig = crate::kernels::lookup(kernel).ok_or_else(|| anyhow!("unknown kernel {kernel}"))?;
    if sig.args.len() != toks.len() {
        anyhow::bail!("{kernel}: {} tokens, expected {}", toks.len(), sig.args.len());
    }
    let mut args = Vec::new();
    for (t, (_, role)) in toks.iter().zip(sig.args) {
        args.push(match role {
            ArgRole::Flag(_) => CallArg::Flag(t.chars().next().unwrap()),
            ArgRole::Scalar => match t.parse::<f64>() {
                Ok(v) => CallArg::Scalar(v),
                Err(_) => CallArg::Expr(Expr::parse(t).map_err(|e| anyhow!(e))?),
            },
            ArgRole::Dim | ArgRole::Ld | ArgRole::Inc => {
                CallArg::Expr(Expr::parse(t).map_err(|e| anyhow!(e))?)
            }
            ArgRole::Data(_) => CallArg::Data(t.trim_start_matches('$').to_string()),
        });
    }
    Call::new(kernel, args)
}

pub(crate) fn base(name: &str, lib: &str) -> Experiment {
    Experiment {
        name: name.into(),
        library: lib.into(),
        machine: "localhost".into(),
        discard_first: true,
        ..Default::default()
    }
}

// =====================================================================
// T1 + T2 — §2 metrics table and PAPI counter table (Experiment 1)
// =====================================================================

pub fn t1_dgemm_metrics(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n = if quick { 200 } else { 500 };
    let ns = n.to_string();
    let mut exp = base("t1-dgemm-metrics", "rustblocked");
    exp.machine = "localhost".into();
    exp.nreps = 4;
    exp.counters = vec!["PAPI_L1_TCM".into(), "PAPI_BR_MSP".into()];
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )?];
    let report = runner.run(&exp)?;
    let mut rows = vec!["metric,value".to_string()];
    for (name, v) in report.metrics_table()? {
        rows.push(format!("{name},{v:.4}"));
    }
    for (i, cname) in exp.counters.iter().enumerate() {
        let v = report.series(Metric::Counter(i), Stat::Median)[0].1;
        rows.push(format!("{cname},{v:.0}"));
    }
    Ok(FigureOutput {
        id: "T1",
        title: format!("§2 metrics table — dgemm n={n} (+ T2 simulated PAPI counters)"),
        figure: None,
        rows,
        notes: format!(
            "paper: n=1000 on SandyBridge/OpenBLAS, 19.1 Gflops/s @91.7%. here: n={n}, \
             rustblocked on 1 core; counters from the cache simulator (§Subst 3)."
        ),
    })
}

// =====================================================================
// F1 — Fig. 1: statistics over 10 repetitions, first-rep outlier
// =====================================================================

pub fn f1_stats(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n = if quick { 150 } else { 400 };
    let ns = n.to_string();
    let mut exp = base("f1-stats", "rustblocked");
    exp.nreps = 10;
    exp.discard_first = false;
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )?];
    let report = runner.run(&exp)?;
    let point = &report.points[0];
    let per_rep = report.rep_values(point, Metric::TimeMs);
    let mut rows = vec!["stat,all reps,without first".to_string()];
    let mut fig = Figure::new("Fig.1 — dgemm timing statistics over 10 reps", "statistic", "time [ms]");
    fig.bars = true;
    let mut with = vec![];
    let mut without = vec![];
    for (i, &stat) in crate::coordinator::stats::ALL_STATS.iter().enumerate() {
        let a = stat.apply(&per_rep);
        let b = stat.apply(&per_rep[1..]);
        rows.push(format!("{},{a:.4},{b:.4}", stat.name()));
        with.push((i as f64, a));
        without.push((i as f64, b));
    }
    fig.add_series("all reps", with);
    fig.add_series("first dropped", without);
    // per-rep series for the outlier visualization
    rows.push(String::new());
    rows.push("rep,time_ms".into());
    for (i, v) in per_rep.iter().enumerate() {
        rows.push(format!("{i},{v:.4}"));
    }
    Ok(FigureOutput {
        id: "F1",
        title: "Fig.1 — repetition statistics (first-execution outlier)".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "x = stat index (min,max,avg,med,std); n={n}. The first repetition is \
             expected to be the max (cold caches) — compare the two bar groups."
        ),
    })
}

// =====================================================================
// F2 — Fig. 2: data placement, warm vs cold C (Experiment 3)
// =====================================================================

pub fn f2_locality(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    // small fixed A,B; C large enough to stream
    let (mk, n) = if quick { (64, 400) } else { (64, 1500) };
    let mks = mk.to_string();
    let ns = n.to_string();
    let build = |vary_c: bool| -> Result<Report> {
        let mut exp = base(if vary_c { "f2-cold" } else { "f2-warm" }, "rustblocked");
        exp.nreps = 16;
        exp.counters = vec!["PAPI_L1_TCM".into(), "PAPI_L3_TCM".into()];
        // C is m×n = n×mk? paper: A,B small, C varies. Use m=n (large),
        // n(cols)=mk small, k=mk: C is n×mk.
        exp.calls = vec![call(
            "dgemm",
            &["N", "N", &ns, &mks, &mks, "1.0", "$A", &ns, "$B", &mks, "1.0", "$C", &ns],
        )?];
        if vary_c {
            exp.vary.insert("C".into(), Vary { with_rep: true, ..Default::default() });
        }
        runner.run(&exp)
    };
    let warm = build(false)?;
    let cold = build(true)?;
    let g_warm = warm.series(Metric::Gflops, Stat::Median)[0].1;
    let g_cold = cold.series(Metric::Gflops, Stat::Median)[0].1;
    let l3_warm = warm.series(Metric::Counter(1), Stat::Median)[0].1;
    let l3_cold = cold.series(Metric::Counter(1), Stat::Median)[0].1;
    let mut fig = Figure::new("Fig.2 — influence of data locality on dgemm", "case (0=warm,1=cold)", "Gflops/s");
    fig.bars = true;
    fig.add_series("warm C (fixed)", vec![(0.0, g_warm)]);
    fig.add_series("cold C (varies/rep)", vec![(1.0, g_cold)]);
    let rows = vec![
        "case,gflops,sim_L3_misses".to_string(),
        format!("warm,{g_warm:.4},{l3_warm:.0}"),
        format!("cold,{g_cold:.4},{l3_cold:.0}"),
    ];
    Ok(FigureOutput {
        id: "F2",
        title: "Fig.2 — warm vs cold C operand".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "C is {n}x{mk} (≈{} MiB): varying it per repetition defeats caching; \
             expect warm ≥ cold in Gflops/s and far fewer simulated L3 misses warm.",
            n * mk * 8 / (1 << 20)
        ),
    })
}

// =====================================================================
// F3 — Fig. 3: breakdown of a kernel sequence (Experiment 4)
// =====================================================================

pub fn f3_breakdown(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (n, nrhs) = if quick { (200, 40) } else { (600, 120) };
    let ns = n.to_string();
    let rs = nrhs.to_string();
    let mut exp = base("f3-breakdown", "rustblocked");
    exp.nreps = 4;
    // B := A⁻¹B via LU + two triangular solves (paper Experiment 4)
    exp.calls = vec![
        call("dgetrf", &[&ns, &ns, "$A", &ns])?,
        call("dtrsm", &["L", "L", "N", "U", &ns, &rs, "1.0", "$A", &ns, "$B", &ns])?,
        call("dtrsm", &["L", "U", "N", "N", &ns, &rs, "1.0", "$A", &ns, "$B", &ns])?,
    ];
    let report = runner.run(&exp)?;
    let breakdown = &report.call_breakdown(Stat::Median)[0];
    let total: f64 = breakdown.iter().map(|(_, v)| v).sum();
    let mut rows = vec!["kernel,seconds,fraction".to_string()];
    let mut fig = Figure::new("Fig.3 — time breakdown: solve A X = B", "call index", "seconds");
    fig.bars = true;
    for (i, (label, secs)) in breakdown.iter().enumerate() {
        rows.push(format!("{label},{secs:.6},{:.3}", secs / total));
        fig.add_series(label, vec![(i as f64, *secs)]);
    }
    Ok(FigureOutput {
        id: "F3",
        title: "Fig.3 — dgetrf + 2×dtrsm breakdown".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "n={n}, nrhs={nrhs}. paper (n=1000, nrhs=200): dgetrf >60%, each dtrsm <20%."
        ),
    })
}

// =====================================================================
// F4 — Fig. 4: dgesv over a parameter range (Experiment 5)
// =====================================================================

pub fn f4_gesv_range(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (hi, nrhs, step) = if quick { (300, 50, 50) } else { (1000, 150, 50) };
    let rs = nrhs.to_string();
    let mut exp = base("f4-gesv", "rustblocked");
    exp.nreps = 3;
    exp.range = Some(RangeDef::span("n", 50, step as i64, hi as i64));
    exp.calls = vec![call("dgesv", &["n", &rs, "$A", "n", "$B", "n"])?];
    exp.datagen.insert("A".into(), DataGen::Spd(Expr::sym("n")));
    let report = runner.run(&exp)?;
    let series = report.series(Metric::Gflops, Stat::Max);
    let mut rows = vec!["n,gflops_max,gflops_med".to_string()];
    let med = report.series(Metric::Gflops, Stat::Median);
    for (i, (x, y)) in series.iter().enumerate() {
        rows.push(format!("{x},{y:.4},{:.4}", med[i].1));
    }
    let mut fig = Figure::new("Fig.4 — dgesv performance vs problem size", "n", "Gflops/s");
    fig.add_iseries("rustblocked", &series);
    Ok(FigureOutput {
        id: "F4",
        title: "Fig.4 — linear system solve over n".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "n = 50:{step}:{hi}, nrhs={nrhs} (paper: 50:50:2000, nrhs=500). Expect \
             monotone performance growth, flattening for large n."
        ),
    })
}

// =====================================================================
// F5 — Fig. 5: eigensolver scalability over threads (Experiment 6)
// =====================================================================

pub fn f5_eig_scalability(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n = if quick { 100 } else { 300 };
    let ns = n.to_string();
    let mut fig = Figure::new(
        "Fig.5 — symmetric eigensolvers, 1..8 threads (simulated threads)",
        "threads",
        "speedup vs 1 thread",
    );
    let mut rows = vec!["driver,threads,time_s,speedup".to_string()];
    let machine = crate::perfmodel::MachineModel::sandybridge();
    for driver in ["dsyev", "dsyevx", "dsyevr", "dsyevd"] {
        // measure the serial time once (median of several reps), then
        // sweep the thread model — one serial sample per driver keeps
        // the curves free of measurement noise (§Subst 4).
        let mut exp = base(&format!("f5-{driver}"), "rustblocked");
        exp.machine = "sandybridge".into();
        exp.nreps = 5;
        exp.calls = vec![call(driver, &["V", "L", &ns, "$A", &ns, "$W"])?];
        exp.datagen.insert("A".into(), DataGen::Spd(Expr::parse(&ns).unwrap()));
        // fresh matrix per repetition: the driver overwrites A with
        // eigenvectors, which would otherwise be re-decomposed
        exp.vary.insert("A".into(), Vary { with_rep: true, ..Default::default() });
        let report = runner.run(&exp)?;
        let serial = report.series(Metric::TimeS, Stat::Median)[0].1;
        let pf = crate::libraries::by_name("rustblocked")
            .unwrap()
            .parallel_fraction(driver);
        let mut pts = Vec::new();
        for t in 1..=8usize {
            let time = crate::perfmodel::scaling::library_threads_time(serial, pf, t, &machine);
            rows.push(format!("{driver},{t},{time:.5},{:.3}", serial / time));
            pts.push((t as i64, serial / time));
        }
        fig.add_iseries(driver, &pts);
    }
    Ok(FigureOutput {
        id: "F5",
        title: "Fig.5 — LAPACK symmetric eigensolver scalability".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "n={n}. SIMULATED THREADS (1-core host): serial times measured, scaled by \
             the Amdahl model with per-driver parallel fractions (§Subst 4). Expect \
             dsyevd/dsyevr to scale best, dsyev worst — the paper's qualitative order."
        ),
    })
}

// =====================================================================
// F6 — Fig. 6: block-size study of triangular inversion (Experiment 7)
// =====================================================================

pub fn f6_blocksize(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n: i64 = if quick { 256 } else { 1024 };
    let nbs: Vec<i64> = if quick {
        vec![8, 16, 32, 64, 128]
    } else {
        vec![8, 16, 32, 64, 96, 128, 192, 256]
    };
    let mut pts = Vec::new();
    let mut rows = vec!["nb,gflops".to_string()];
    for &nb in &nbs {
        let nbs_ = nb.to_string();
        let mut exp = base(&format!("f6-nb{nb}"), "rustblocked");
        exp.nreps = 3;
        // sum-range over the diagonal-block index i = 0, nb, …, n-nb:
        // per step (paper Experiment 7): dtrmm (update), dtrsm (scale),
        // dtrti2 (invert diagonal block). Sizes are expressions in i.
        let steps: Vec<i64> = (0..n).step_by(nb as usize).collect();
        exp.sumrange = Some(RangeDef::new("i", steps));
        let rem = format!("max({n} - i - {nb}, 0)");
        let remld = format!("max({n} - i - {nb}, 1)");
        exp.calls = vec![
            call(
                "dtrmm",
                &["L", "L", "N", "N", &rem, &nbs_, "1.0", "$A22", &remld, "$A21", &remld],
            )?,
            call(
                "dtrsm",
                &["R", "L", "N", "N", &rem, &nbs_, "-1.0", "$A11", &nbs_, "$A21", &remld],
            )?,
            call("dtrti2", &["L", "N", &nbs_, "$A11", &nbs_])?,
        ];
        exp.datagen.insert("A22".into(), DataGen::Tri(Expr::parse(&remld).unwrap(), 'L'));
        exp.datagen.insert("A11".into(), DataGen::Tri(Expr::Const(nb), 'L'));
        let report = runner.run(&exp)?;
        // report Gflops against the true trtri flop count n³/3
        let secs = report.series(Metric::TimeS, Stat::Median)[0].1;
        let gflops = (n as f64).powi(3) / 3.0 / secs / 1e9;
        rows.push(format!("{nb},{gflops:.4}"));
        pts.push((nb, gflops));
    }
    let mut fig = Figure::new(
        &format!("Fig.6 — blocked triangular inversion, n={n}"),
        "block size nb",
        "Gflops/s",
    );
    fig.add_iseries("rustblocked", &pts);
    let best = pts.iter().cloned().fold((0i64, 0.0f64), |a, b| if b.1 > a.1 { b } else { a });
    Ok(FigureOutput {
        id: "F6",
        title: "Fig.6 — block-size tuning of dtrtri".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "best nb = {} at {:.2} Gflops/s (paper: n=1000, optimum nb=100). Expect an \
             interior optimum: tiny nb ⇒ blas-2 bound, huge nb ⇒ unblocked dtrti2 bound.",
            best.0, best.1
        ),
    })
}

// =====================================================================
// F7 — Fig. 7: threaded dtrsm vs parallel dtrsv's (Experiments 8+9)
// =====================================================================

pub fn f7_trsm_vs_trsv(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (hi, step, nrhs) = if quick { (600i64, 200i64, 8usize) } else { (2000, 250, 8) };
    let machine = crate::perfmodel::MachineModel::sandybridge();
    // The paper's observation (Fig. 7) is that the vendor dtrsm
    // parallelizes poorly on extremely skewed shapes — threading an
    // n×n solve with only 8 right-hand-side columns leaves most of the
    // per-column dependency chain serial. We model the threaded trsm
    // with a skewed-shape parallel fraction calibrated to that
    // observation; the dtrsv tasks are embarrassingly parallel.
    const TRSM_SKEWED_PF: f64 = 0.55;
    let mut rows = vec!["n,threaded_dtrsm_s,omp_dtrsv_s".to_string()];
    let mut s8_pts = Vec::new();
    let mut s9_pts = Vec::new();
    let rs = nrhs.to_string();
    let mut n = step;
    while n <= hi {
        let nstr = n.to_string();
        // serial dtrsm (one call, nrhs columns)
        let mut e_trsm = base(&format!("f7-trsm-{n}"), "rustblocked");
        e_trsm.machine = "sandybridge".into();
        e_trsm.nreps = 4;
        e_trsm.calls = vec![call(
            "dtrsm",
            &["L", "L", "N", "N", &nstr, &rs, "1.0", "$A", &nstr, "$B", &nstr],
        )?];
        e_trsm.datagen.insert("A".into(), DataGen::Tri(Expr::parse(&nstr).unwrap(), 'L'));
        let serial_trsm =
            runner.run(&e_trsm)?.series(Metric::TimeS, Stat::Median)[0].1;
        // serial dtrsv (one column)
        let mut e_trsv = base(&format!("f7-trsv-{n}"), "rustblocked");
        e_trsv.machine = "sandybridge".into();
        e_trsv.nreps = 4;
        e_trsv.calls = vec![call("dtrsv", &["L", "N", "N", &nstr, "$A", &nstr, "$x", "1"])?];
        e_trsv.datagen.insert("A".into(), DataGen::Tri(Expr::parse(&nstr).unwrap(), 'L'));
        let serial_trsv =
            runner.run(&e_trsv)?.series(Metric::TimeS, Stat::Median)[0].1;
        let t_trsm = crate::perfmodel::scaling::library_threads_time(
            serial_trsm, TRSM_SKEWED_PF, 8, &machine,
        );
        let t_omp = crate::perfmodel::scaling::omp_tasks_time(
            serial_trsv, nrhs, 8, 1, 0.0, &machine,
        );
        rows.push(format!("{n},{t_trsm:.6},{t_omp:.6}"));
        s8_pts.push((n, t_trsm));
        s9_pts.push((n, t_omp));
        n += step;
    }
    let mut fig = Figure::new(
        "Fig.7 — threaded dtrsm vs parallel dtrsv's (simulated threads)",
        "n",
        "seconds",
    );
    fig.add_iseries("dtrsm, 8 lib threads (skewed-shape pf)", &s8_pts);
    fig.add_iseries(&format!("{nrhs}× dtrsv via OpenMP"), &s9_pts);
    Ok(FigureOutput {
        id: "F7",
        title: "Fig.7 — two multi-threading strategies for a tall-skinny solve".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "rhs = {nrhs} columns. SIMULATED THREADS; threaded trsm uses a skewed-shape \
             parallel fraction of {TRSM_SKEWED_PF} calibrated to the paper's observation \
             that OpenBLAS's trsm threading collapses on such shapes. Expect the OpenMP \
             dtrsv's to win — the paper's finding."
        ),
    })
}

// =====================================================================
// F11 — Fig. 11: tensor contraction algorithm selection (Exps 10+11)
// =====================================================================

/// Scaled contraction sizes (paper /4): A ∈ R^{312×188},
/// B ∈ R^{188×125×n}, C ∈ R^{312×n×125}.
pub const TC_M: i64 = 312;
pub const TC_K: i64 = 188;
pub const TC_B: i64 = 125;
pub const TC_N_SWEEP: &[i64] = &[25, 50, 75, 100, 150, 200, 300, 400, 500, 625];

pub fn f11_tensor_contraction(
    runner: &dyn ExperimentRunner,
    quick: bool,
) -> Result<FigureOutput> {
    // prefer the xla (PJRT vendor) backend; fall back to rustblocked
    let lib = if crate::libraries::by_name("xla").is_some() { "xla" } else { "rustblocked" };
    let sweep: Vec<i64> = if quick {
        vec![25, 75, 150, 300]
    } else {
        TC_N_SWEEP.to_vec()
    };
    let (ms, ks, bs) = (TC_M.to_string(), TC_K.to_string(), TC_B.to_string());
    // ∀b: n gemms of fixed size (312×188)·(188×125) on varying data —
    // efficiency is n-independent, so one experiment suffices (paper
    // Experiment 10 does exactly this with 10 reps).
    let mut eb = base("f11-forall-b", lib);
    eb.nreps = 10;
    eb.calls = vec![call(
        "dgemm",
        &["N", "N", &ms, &bs, &ks, "1.0", "$A", &ms, "$B", &ks, "0.0", "$C", &ms],
    )?];
    eb.vary.insert("B".into(), Vary { with_rep: true, ..Default::default() });
    eb.vary.insert("C".into(), Vary { with_rep: true, ..Default::default() });
    let rb = runner.run(&eb)?;
    let gb = rb.series(Metric::Gflops, Stat::Median)[0].1;
    // ∀c: 125 gemms of (312×188)·(188×n) — n-dependent efficiency
    let mut ec = base("f11-forall-c", lib);
    ec.nreps = 10;
    ec.range = Some(RangeDef::new("n", sweep.clone()));
    ec.calls = vec![call(
        "dgemm",
        &["N", "N", &ms, "n", &ks, "1.0", "$A", &ms, "$B", &ks, "0.0", "$C", &ms],
    )?];
    ec.vary.insert("B".into(), Vary { with_rep: true, ..Default::default() });
    ec.vary.insert("C".into(), Vary { with_rep: true, ..Default::default() });
    let rc = runner.run(&ec)?;
    let sc = rc.series(Metric::Gflops, Stat::Median);
    let mut rows = vec!["n,forall_b_gflops,forall_c_gflops".to_string()];
    let sb: Vec<(i64, f64)> = sweep.iter().map(|&n| (n, gb)).collect();
    for (i, &n) in sweep.iter().enumerate() {
        rows.push(format!("{n},{gb:.4},{:.4}", sc[i].1));
    }
    let mut fig = Figure::new(
        "Fig.11 — dgemm-based tensor contraction algorithms",
        "n",
        "Gflops/s",
    );
    fig.add_iseries("∀b (fixed-size gemms)", &sb);
    fig.add_iseries("∀c (n-dependent gemms)", &sc);
    // crossover
    let crossover = sweep
        .iter()
        .enumerate()
        .find(|&(i, _)| sc[i].1 > gb)
        .map(|(_, &n)| n);
    Ok(FigureOutput {
        id: "F11",
        title: "Fig.11 — C_abc := A_ak B_kcb algorithm selection".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "backend={lib}; sizes scaled /4 from the paper (A 312×188, B-depth {TC_B}). \
             crossover at n = {:?} (paper: ∀c overtakes ∀b before n = depth, at \
             n≈300 of 500 — i.e. ≈0.6·depth ≈ {} here).",
            crossover,
            (0.6 * TC_B as f64) as i64
        ),
    })
}

// =====================================================================
// F12 — Fig. 12: library selection for the Sylvester equation (Exp 12)
// =====================================================================

pub fn f12_sylvester(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (hi, step) = if quick { (200i64, 50i64) } else { (600, 50) };
    let libs: &[(&str, &str)] = &[
        ("rustref", "LAPACK-analog (unblocked; also the paper's MKL)"),
        ("rustblocked", "libFLAME-analog (blocked)"),
        ("rustrecursive", "RECSY-analog (recursive)"),
    ];
    let mut fig = Figure::new(
        "Fig.12 — triangular Sylvester equation across libraries",
        "m = n",
        "Gflops/s",
    );
    let mut rows = vec!["n,".to_string() + &libs.iter().map(|(l, _)| *l).collect::<Vec<_>>().join(",")];
    let mut table: Vec<Vec<f64>> = vec![];
    let mut xs: Vec<i64> = vec![];
    // all three library sweeps as one batch through the engine's
    // scheduler (their points interleave across the worker pool)
    let mut exps = Vec::with_capacity(libs.len());
    for (lib, _) in libs {
        let mut exp = base(&format!("f12-{lib}"), lib);
        exp.nreps = 3;
        exp.range = Some(RangeDef::span("n", step, step, hi));
        exp.calls = vec![call(
            "dtrsyl",
            &["N", "N", "1", "n", "n", "$A", "n", "$B", "n", "$C", "n"],
        )?];
        exp.datagen.insert("A".into(), DataGen::Tri(Expr::sym("n"), 'U'));
        exp.datagen.insert("B".into(), DataGen::Tri(Expr::sym("n"), 'U'));
        exps.push(exp);
    }
    let reports = runner.run_batch(&exps)?;
    for ((_, label), report) in libs.iter().zip(&reports) {
        let s = report.series(Metric::Gflops, Stat::Median);
        if xs.is_empty() {
            xs = s.iter().map(|&(x, _)| x).collect();
            table = vec![vec![]; xs.len()];
        }
        for (i, &(_, g)) in s.iter().enumerate() {
            table[i].push(g);
        }
        fig.add_iseries(label, &s);
    }
    for (i, &x) in xs.iter().enumerate() {
        rows.push(format!(
            "{x},{}",
            table[i].iter().map(|g| format!("{g:.4}")).collect::<Vec<_>>().join(",")
        ));
    }
    Ok(FigureOutput {
        id: "F12",
        title: "Fig.12 — dtrsyl library comparison".into(),
        figure: Some(fig),
        rows,
        notes: "paper: RECSY ≫ libFLAME > LAPACK ≈ MKL. expected here: recursive > \
                blocked > unblocked, with the unblocked variant flat/declining."
            .into(),
    })
}

// =====================================================================
// F13 — Fig. 13: multi-threading paradigms for a sequence of LUs
// =====================================================================

pub fn f13_lu_threading(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n: i64 = if quick { 128 } else { 320 };
    let counts: Vec<usize> = (1..=16).collect();
    let ns = n.to_string();
    let machine = crate::perfmodel::MachineModel::haswell_laptop();
    // Measure the serial dgetrf time once (median over reps, fresh
    // matrix per rep) — per-count re-measurement would bury the model
    // in noise on this 1-core host (§Subst 4).
    let mut exp = base("f13-serial-lu", "rustblocked");
    exp.machine = "haswell".into();
    exp.nreps = if quick { 4 } else { 6 };
    exp.calls = vec![call("dgetrf", &[&ns, &ns, "$A", &ns])?];
    exp.vary.insert("A".into(), Vary { with_rep: true, ..Default::default() });
    let report = runner.run(&exp)?;
    let serial = report.series(Metric::TimeS, Stat::Median)[0].1;
    let task_flops = report.points[0].records[0].flops;
    let pf = crate::libraries::by_name("rustblocked").unwrap().parallel_fraction("dgetrf");
    // paradigms: (omp threads, inner threads, label)
    let paradigms: &[(usize, usize, &str)] = &[
        (1, 8, "multi-threaded dgetrf"),
        (8, 1, "OpenMP × sequential dgetrf"),
        (8, 8, "hybrid (OpenMP × up-to-8-thread dgetrf)"),
    ];
    let mut series: Vec<Vec<(i64, f64)>> = vec![vec![]; paradigms.len()];
    let mut rows =
        vec!["count,".to_string() + &paradigms.iter().map(|p| p.2).collect::<Vec<_>>().join(",")];
    for &count in &counts {
        let mut vals = vec![];
        for (pi, &(omp, inner, _)) in paradigms.iter().enumerate() {
            let t = crate::perfmodel::scaling::omp_tasks_time(
                serial, count, omp, inner, pf, &machine,
            );
            let g = task_flops * count as f64 / t / 1e9;
            series[pi].push((count as i64, g));
            vals.push(g);
        }
        rows.push(format!(
            "{count},{}",
            vals.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(",")
        ));
    }
    let mut fig = Figure::new(
        &format!("Fig.13 — LU sequence (n={n}) threading paradigms (simulated threads)"),
        "number of LU decompositions",
        "aggregate Gflops/s",
    );
    for (pi, (_, _, label)) in paradigms.iter().enumerate() {
        fig.add_iseries(label, &series[pi]);
    }
    Ok(FigureOutput {
        id: "F13",
        title: "Fig.13 — §4.3 sequence-of-LUs study".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "SIMULATED THREADS on the haswell model (8 hw threads); serial dgetrf \
             measured once ({:.2} ms median), paradigms derived via the task model. \
             paper: beyond 8 LUs, OpenMP×sequential beats the threaded kernel; the \
             hybrid wins overall.",
            serial * 1e3
        ),
    })
}

// =====================================================================
// F14 — Fig. 14: GWAS generalized least squares (Experiments 15+16)
// =====================================================================

pub fn f14_gwas(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let n: i64 = if quick { 150 } else { 500 };
    let p: i64 = 4;
    let ms: Vec<usize> = if quick { vec![2, 4, 8] } else { vec![2, 4, 8, 16, 32] };
    let ns = n.to_string();
    let ps = p.to_string();
    let mut naive_pts = vec![];
    let mut opt_pts = vec![];
    let mut rows = vec!["m,naive_s,optimized_s,speedup".to_string()];
    let mut naive_breakdown: Vec<(String, f64)> = vec![];
    for &m in &ms {
        // naive: per iteration i — dposv(M_i, V=X_i), S = XᵀV,
        // dgemv (w = Vᵀ y), dposv(S, b)
        let mut exp = base(&format!("f14-naive-{m}"), "rustblocked");
        exp.nreps = 3;
        exp.sumrange = Some(RangeDef::new("i", (0..m as i64).collect()));
        // S := Vᵀ·V with V = M⁻¹X — a Gram matrix, so the small dposv
        // stays positive definite across iterations (the paper's
        // S = XᵀM⁻¹X; same shapes and cost)
        exp.calls = vec![
            call("dposv", &["L", &ns, &ps, "$M", &ns, "$V", &ns])?,
            call("dgemm", &["T", "N", &ps, &ps, &ns, "1.0", "$V", &ns, "$V", &ns, "0.0", "$S", &ps])?,
            call("dgemv", &["T", &ns, &ps, "1.0", "$V", &ns, "$y", "1", "0.0", "$w", "1"])?,
            call("dposv", &["L", &ps, "1", "$S", &ps, "$w2", &ps])?,
        ];
        exp.datagen.insert("M".into(), DataGen::Spd(Expr::parse(&ns).unwrap()));
        // fresh M per iteration AND repetition: dposv overwrites it
        // with its (non-SPD) Cholesky factor
        exp.vary
            .insert("M".into(), Vary { with_sumrange: true, with_rep: true, pad_elems: 0 });
        // fresh V too: dposv overwrites it with M⁻¹V, and reusing it
        // would shrink it towards zero over the m iterations (‖M⁻¹‖≪1)
        exp.vary
            .insert("V".into(), Vary { with_sumrange: true, with_rep: true, pad_elems: 0 });
        let rn = runner.run(&exp)?;
        let tn = rn.series(Metric::TimeS, Stat::Median)[0].1;
        naive_pts.push((m as i64, tn));
        if m == *ms.last().unwrap() {
            naive_breakdown = rn.call_breakdown(Stat::Median)[0].clone();
        }
        // optimized: hoist dposv out of the loop, batch all right-hand
        // sides into one dpotrs (paper Experiment 16)
        let pm = (p as usize * m).to_string();
        let mut opt = base(&format!("f14-opt-{m}"), "rustblocked");
        opt.nreps = 3;
        opt.calls = vec![
            call("dposv", &["L", &ns, "1", "$M", &ns, "$y", &ns])?,
            call("dpotrs", &["L", &ns, &pm, "$M", &ns, "$Xall", &ns])?,
        ];
        opt.datagen.insert("M".into(), DataGen::Spd(Expr::parse(&ns).unwrap()));
        opt.vary.insert("M".into(), Vary { with_rep: true, ..Default::default() });
        let ro = runner.run(&opt)?;
        let to = ro.series(Metric::TimeS, Stat::Median)[0].1;
        opt_pts.push((m as i64, to));
        rows.push(format!("{m},{tn:.5},{to:.5},{:.1}", tn / to));
    }
    let mut fig = Figure::new(
        &format!("Fig.14 — GWAS GLS sequence, n={n}, p={p}"),
        "m (GLS instances)",
        "seconds",
    );
    fig.add_iseries("naive (per-i dposv)", &naive_pts);
    fig.add_iseries("optimized (hoisted + batched dpotrs)", &opt_pts);
    rows.push(String::new());
    rows.push("naive breakdown (largest m): kernel,seconds".into());
    for (k, v) in &naive_breakdown {
        rows.push(format!("{k},{v:.5}"));
    }
    Ok(FigureOutput {
        id: "F14",
        title: "Fig.14 — GWAS timing breakdown and algorithmic optimization".into(),
        figure: Some(fig),
        rows,
        notes: "paper: runtime dominated by dposv/dpotrs; hoisting + batching gains \
                >10× for large m. Expect the naive curve linear in m, the optimized \
                one nearly flat, and dposv dominating the naive breakdown."
            .into(),
    })
}

// =====================================================================
// W1 — warm vs cold execution (engine warm mode; the paper's Fig. 2
// cache-locality scenario, carried *across* campaign points)
// =====================================================================

/// Back-to-back campaign execution: the same cache-resident dgemm point
/// repeated over a sweep, measured cold (the paper's default — a fresh
/// sampler per point, every point starts from empty simulated caches)
/// and warm (engine warm mode — one sampler carries simulated cache
/// state from point to point, as if the campaign ran back-to-back on a
/// live machine).
pub fn w1_warm_execution(runner: &dyn ExperimentRunner, quick: bool) -> Result<FigureOutput> {
    let (n, npoints): (i64, i64) = if quick { (64, 4) } else { (128, 8) };
    let ns = n.to_string();
    let mut exp = base("w1-warm-vs-cold", "rustblocked");
    exp.nreps = 2;
    // the cold-start cost of each point IS the signal here — keep the
    // first repetition in the statistics
    exp.discard_first = false;
    exp.counters = vec!["PAPI_L1_TCM".into(), "PAPI_L3_TCM".into()];
    // the same point repeated: range_value is a run index; the script
    // (and therefore the operand working set) is identical per point
    exp.range = Some(RangeDef::new("run", (1..=npoints).collect()));
    exp.calls = vec![call(
        "dgemm",
        &["N", "N", &ns, &ns, &ns, "1.0", "$A", &ns, "$B", &ns, "0.0", "$C", &ns],
    )?];
    let cold = runner.run_cold(&exp)?;
    let warm = runner.run_warm(&exp)?;
    let cold_l3 = cold.series(Metric::Counter(1), Stat::Max);
    let warm_l3 = warm.series(Metric::Counter(1), Stat::Max);
    let cold_l1 = cold.series(Metric::Counter(0), Stat::Max);
    let warm_l1 = warm.series(Metric::Counter(0), Stat::Max);
    let mut rows = vec!["point,cold_L3_TCM,warm_L3_TCM,cold_L1_TCM,warm_L1_TCM".to_string()];
    for i in 0..cold_l3.len() {
        rows.push(format!(
            "{},{:.0},{:.0},{:.0},{:.0}",
            i + 1,
            cold_l3[i].1,
            warm_l3[i].1,
            cold_l1[i].1,
            warm_l1[i].1
        ));
    }
    let mut fig = Figure::new(
        "W1 — warm vs cold execution across campaign points",
        "point index",
        "sim. L3 misses (max over reps)",
    );
    fig.add_series(
        "cold (fresh sampler per point)",
        cold_l3.iter().enumerate().map(|(i, &(_, v))| ((i + 1) as f64, v)).collect(),
    );
    fig.add_series(
        "warm (carried sampler state)",
        warm_l3.iter().enumerate().map(|(i, &(_, v))| ((i + 1) as f64, v)).collect(),
    );
    Ok(FigureOutput {
        id: "W1",
        title: "W1 — warm vs cold back-to-back execution".into(),
        figure: Some(fig),
        rows,
        notes: format!(
            "dgemm n={n}, {npoints} identical points. Cold: every point re-misses its \
             operands (the paper's per-point sampler start). Warm: point 1 matches cold \
             (no carried state yet), later points find A/B/C simulated-resident — the \
             cache-locality effect of Fig. 2, carried across campaign points."
        ),
    })
}

// =====================================================================

/// A figure builder: assembles one figure's output through the given
/// runner.
pub type FigureBuilder = fn(&dyn ExperimentRunner, bool) -> Result<FigureOutput>;

/// All figure builders in paper order.
pub fn all_builders() -> Vec<(&'static str, FigureBuilder)> {
    vec![
        ("T1", t1_dgemm_metrics),
        ("F1", f1_stats),
        ("F2", f2_locality),
        ("F3", f3_breakdown),
        ("F4", f4_gesv_range),
        ("F5", f5_eig_scalability),
        ("F6", f6_blocksize),
        ("F7", f7_trsm_vs_trsv),
        ("F11", f11_tensor_contraction),
        ("F12", f12_sylvester),
        ("F13", f13_lu_threading),
        ("F14", f14_gwas),
        ("W1", w1_warm_execution),
    ]
}

/// All builders addressable by id: the paper figures plus the
/// scenario pack ([`scenarios::scenario_builders`], ids S1…).
pub fn builder_registry() -> Vec<(&'static str, FigureBuilder)> {
    let mut v = all_builders();
    v.extend(scenarios::scenario_builders());
    v
}

/// Run one figure by id, executing immediately (the standalone path).
pub fn run_figure(id: &str, quick: bool) -> Result<FigureOutput> {
    let builder = builder_registry()
        .into_iter()
        .find(|(fid, _)| fid.eq_ignore_ascii_case(id))
        .ok_or_else(|| anyhow!("unknown figure id '{id}'"))?;
    (builder.1)(&LocalRunner, quick).with_context(|| format!("figure {id}"))
}

/// The result of one figure campaign: the completed outputs, the one
/// batch's statistics, and any per-figure failures from the replay
/// pass (the measurements those figures consumed are not lost — with a
/// cache configured they replay for free on the next attempt).
pub struct CampaignOutcome {
    /// Completed figure outputs, in request order (failed ones absent).
    pub outputs: Vec<FigureOutput>,
    /// Statistics of the campaign's single engine batch.
    pub stats: BatchStats,
    /// Figures whose replay pass failed: (figure id, error).
    pub failures: Vec<(String, anyhow::Error)>,
}

/// Run a whole figure campaign through **one** engine batch: plan every
/// requested builder, measure all collected experiments via a single
/// [`crate::engine::Engine::run_batch_stats`] (campaign-level sharding
/// and cache probing), then replay the builders against the measured
/// reports. Errors before measurement (unknown id, plan-pass or batch
/// failure) abort the whole campaign; a failure while assembling one
/// figure's output does **not** discard the other figures — it is
/// reported in [`CampaignOutcome::failures`] instead.
pub fn run_figures_campaign(ids: &[String], quick: bool) -> Result<CampaignOutcome> {
    let registry = builder_registry();
    let mut builders: Vec<(&'static str, FigureBuilder)> = Vec::new();
    for id in ids {
        let found = registry
            .iter()
            .find(|(fid, _)| fid.eq_ignore_ascii_case(id))
            .ok_or_else(|| anyhow!("unknown figure id '{id}'"))?;
        builders.push(*found);
    }
    // pass 1: collect every builder's experiments without measuring
    let plan = PlanRunner::default();
    for (id, builder) in &builders {
        builder(&plan, quick).with_context(|| format!("planning figure {id}"))?;
    }
    let exps = plan.into_experiments();
    // the campaign's single batch submission
    let (reports, stats) =
        crate::engine::Engine::with_defaults().run_batch_stats(&exps)?;
    // pass 2: assemble outputs from the measured reports
    let replay = ReplayRunner::new(&exps, reports);
    let mut outcome = CampaignOutcome {
        outputs: Vec::with_capacity(builders.len()),
        stats,
        failures: Vec::new(),
    };
    for (id, builder) in &builders {
        match builder(&replay, quick).with_context(|| format!("figure {id}")) {
            Ok(out) => outcome.outputs.push(out),
            Err(e) => outcome.failures.push((id.to_string(), e)),
        }
    }
    Ok(outcome)
}

/// Entry point shared by the `rust/benches/fig_*.rs` bench binaries
/// (harness = false): runs one figure, prints the rows + ASCII plot,
/// and writes CSV/SVG/TXT into `figures_out/`.
///
/// `ELAPS_BENCH_FULL=1` switches from quick to full paper-scaled sizes;
/// `ELAPS_JOBS` / `ELAPS_CACHE` configure the execution engine's worker
/// pool and result cache (picked up via the default engine config).
pub fn bench_main(id: &str) {
    let quick = std::env::var("ELAPS_BENCH_FULL").map(|v| v != "1").unwrap_or(true);
    // make the xla backend resolvable when artifacts exist
    let dir = crate::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        if let Err(e) = crate::runtime::register_xla_library(&dir) {
            eprintln!("note: xla backend unavailable: {e:#}");
        }
    }
    let t0 = std::time::Instant::now();
    match run_figure(id, quick) {
        Ok(out) => {
            println!("=== {} — {} (quick={quick}) ===", out.id, out.title);
            for r in &out.rows {
                println!("{r}");
            }
            if let Some(fig) = &out.figure {
                println!("{}", fig.to_ascii(70, 18));
            }
            println!("note: {}", out.notes);
            let dir = std::path::Path::new("figures_out");
            if let Err(e) = out.write_to(dir) {
                eprintln!("warning: could not write {dir:?}: {e:#}");
            } else {
                println!("wrote figures_out/{}.{{csv,svg,txt}}", out.id);
            }
            println!("bench wall time: {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("figure {id} failed: {e:#}");
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn call_builder_parses_tokens() {
        let c = call(
            "dgemm",
            &["N", "N", "n", "n", "n", "1.0", "$A", "n", "$B", "n", "0.0", "$C", "n"],
        )
        .unwrap();
        assert_eq!(c.kernel, "dgemm");
        assert!(matches!(c.args[6], CallArg::Data(ref d) if d == "A"));
        assert!(call("dgemm", &["N", "N"]).is_err());
    }

    #[test]
    fn t1_runs_quick() {
        let out = t1_dgemm_metrics(&LocalRunner, true).unwrap();
        assert!(out.rows.iter().any(|r| r.starts_with("Gflops")));
        assert!(out.rows.iter().any(|r| r.starts_with("PAPI_L1_TCM")));
        let gflops: f64 = out
            .rows
            .iter()
            .find(|r| r.starts_with("Gflops"))
            .and_then(|r| r.split(',').nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap();
        assert!(gflops > 0.05, "{gflops}");
    }

    #[test]
    fn f1_first_rep_is_outlier_shaped() {
        let out = f1_stats(&LocalRunner, true).unwrap();
        // with-first max ≥ without-first max
        let maxrow = out.rows.iter().find(|r| r.starts_with("max,")).unwrap();
        let parts: Vec<f64> =
            maxrow.split(',').skip(1).map(|v| v.parse().unwrap()).collect();
        assert!(parts[0] >= parts[1] * 0.999);
    }

    #[test]
    fn f6_has_interior_shape() {
        let out = f6_blocksize(&LocalRunner, true).unwrap();
        // all rows parse and are positive
        for r in &out.rows[1..] {
            let g: f64 = r.split(',').nth(1).unwrap().parse().unwrap();
            assert!(g > 0.0);
        }
    }

    #[test]
    fn w1_warm_mode_is_observable() {
        let out = w1_warm_execution(&LocalRunner, true).unwrap();
        assert_eq!(out.id, "W1");
        // rows: header + one per point; columns are simulated counters
        // (deterministic), so the warm/cold relationship is exact
        let mut cold_sum = 0.0;
        let mut warm_sum = 0.0;
        let mut first = true;
        for r in &out.rows[1..] {
            let cols: Vec<f64> =
                r.split(',').skip(1).map(|v| v.parse().unwrap()).collect();
            if first {
                // point 1: no carried state yet — warm ≡ cold
                assert_eq!(cols[0], cols[1], "{r}");
                first = false;
            }
            cold_sum += cols[0];
            warm_sum += cols[1];
        }
        assert!(cold_sum > 0.0, "cold points must miss");
        assert!(
            warm_sum < cold_sum,
            "carried state must reduce misses: warm {warm_sum} vs cold {cold_sum}"
        );
    }

    #[test]
    fn unknown_figure_id_rejected() {
        assert!(run_figure("F99", true).is_err());
        assert!(run_figures_campaign(&["F99".into()], true).is_err());
    }

    #[test]
    fn plan_runner_collects_without_measuring() {
        let plan = PlanRunner::default();
        // T1 through the plan pass finishes instantly and records its
        // single experiment; the placeholder output is shaped but fake
        let out = t1_dgemm_metrics(&plan, true).unwrap();
        assert!(out.rows.iter().any(|r| r.starts_with("Gflops")));
        let exps = plan.into_experiments();
        assert_eq!(exps.len(), 1);
        assert_eq!(exps[0].name, "t1-dgemm-metrics");
    }

    #[test]
    fn campaign_matches_standalone_outputs() {
        let ids: Vec<String> = vec!["T1".into(), "F1".into()];
        let outcome = run_figures_campaign(&ids, true).unwrap();
        assert!(outcome.failures.is_empty());
        let (outs, stats) = (&outcome.outputs, &outcome.stats);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].id, "T1");
        assert_eq!(outs[1].id, "F1");
        // every point of both builders went through the one batch
        assert_eq!(stats.experiments, 2);
        assert!(stats.total_points() >= 2);
        assert_eq!(stats.executed, stats.total_points(), "no cache configured");
        // deterministic columns (simulated counters — wall times are
        // not comparable across runs) agree with the standalone path
        let solo = t1_dgemm_metrics(&LocalRunner, true).unwrap();
        let pick = |out: &FigureOutput, prefix: &str| -> String {
            out.rows.iter().find(|r| r.starts_with(prefix)).unwrap().clone()
        };
        assert_eq!(pick(&outs[0], "PAPI_L1_TCM"), pick(&solo, "PAPI_L1_TCM"));
        assert_eq!(pick(&outs[0], "PAPI_BR_MSP"), pick(&solo, "PAPI_BR_MSP"));
    }
}
